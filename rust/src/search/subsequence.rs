//! UCR-style subsequence similarity search (paper §5's workload): slide a
//! z-normalised query over a long reference stream, z-normalising every
//! candidate window on the fly, and collect the top-k matches under an
//! elastic [`Metric`] (windowed DTW by default), pruning with the suite's
//! cascade along the way where the metric's bounds are valid — metrics
//! outside the DTW family ([`Metric::uses_envelopes`] is false) run the
//! bound-free EAPruned scan, still threshold-driven via [`TopK`].
//!
//! The early-abandon threshold is the k-th best distance of a
//! [`TopK`] collector (`k = 1` reproduces the paper's scalar best-so-far
//! bit-for-bit); candidate statistics come either from the seed's
//! streaming recurrence ([`crate::norm::znorm::WindowStats`]) or from a
//! shared precomputed table ([`ScanStats::Indexed`], see
//! [`crate::index::ref_index::RefIndex`]).
//!
//! The loop is allocation-free per candidate: all buffers live in
//! [`QueryContext`] and are reused across the scan.

use anyhow::Result;

use crate::bounds::batch::{
    batch_lb_kim_into, lb_keogh_ec_unordered, lb_keogh_eq_unordered, StripScratch, DEFAULT_STRIP,
};
use crate::bounds::cascade::CascadePolicy;
use crate::bounds::envelope::envelopes_into;
use crate::bounds::lb_improved::{lb_improved_tail_ec, lb_improved_tail_ec_raw, ImprovedScratch};
use crate::bounds::lb_keogh::{
    cumulate_bound, lb_keogh_ec, lb_keogh_eq, lb_keogh_eq_pre, reorder, sort_order,
};
use crate::bounds::lb_kim::lb_kim_hierarchy;
use crate::distances::cache::CostModelCache;
use crate::distances::eap_dtw::eap_cdtw_eval_f32;
use crate::distances::kernel::Precision;
use crate::distances::metric::Metric;
use crate::distances::KernelWorkspace;
use crate::index::ref_index::BucketStats;
use crate::index::topk::TopK;
use crate::metrics::Counters;
use crate::norm::znorm::{znorm, znorm_point, WindowStats};
use crate::obs::{DistKind, ScanObs, Stage};
use crate::search::lanes::LanePacker;
use crate::search::suite::Suite;

/// A located subsequence match.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Match {
    /// start position in the reference stream
    pub pos: usize,
    /// DTW distance (z-normalised, squared-Euclidean cost)
    pub dist: f64,
}

/// Convert the paper's window *ratio* (0.1–0.5 in the grid) to cells,
/// capped at `qlen`: a band wider than the query is equivalent to the
/// unbanded case, and the cap keeps a hostile ratio (`1e999` parses as
/// +inf on the wire) from exploding the envelope build. The float→int
/// cast saturates, so NaN maps to 0 and +inf to the cap.
pub fn window_cells(qlen: usize, ratio: f64) -> usize {
    ((ratio * qlen as f64).floor() as usize).min(qlen)
}

/// How the scan front-end walks the candidate space.
///
/// Both modes return **bitwise-identical top-k results** (same positions,
/// same distances — pinned by `tests/conformance_strip.rs`); they differ
/// only in throughput and in which counter a prune is attributed to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScanMode {
    /// The seed's per-candidate loop: cascade + kernel one candidate at a
    /// time, ascending position. The A/B baseline.
    Scalar,
    /// Strip-mined pipeline (the default serving path): candidates are
    /// processed in strips of [`DEFAULT_STRIP`], the cheap bounds run
    /// batched over SoA scratch lanes, and the survivors are evaluated in
    /// ascending-lower-bound order with a single-pass z-normalisation.
    #[default]
    Strip,
}

impl ScanMode {
    pub fn name(&self) -> &'static str {
        match self {
            ScanMode::Scalar => "scalar",
            ScanMode::Strip => "strip",
        }
    }

    pub fn from_name(s: &str) -> Option<ScanMode> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "legacy" => Some(ScanMode::Scalar),
            "strip" => Some(ScanMode::Strip),
            _ => None,
        }
    }
}

/// Optional widening knobs for a scan, carried per query: how many
/// survivor candidates the wavefront kernel advances in lockstep
/// (`lanes`, 1 = scalar, clamped to
/// [`crate::distances::kernel::MAX_LANES`]) and the DP line storage
/// width (`precision`). The defaults reproduce the pre-tuning scan
/// bit-for-bit; `lanes >= 2` keeps the top-k *contents* bitwise
/// identical on f64 (pinned by `tests/conformance_lanes.rs`) while
/// changing counter attribution; `Precision::F32` trades bitwise
/// equality for the epsilon contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScanTuning {
    /// survivor lanes per wavefront kernel invocation (1 = off)
    pub lanes: usize,
    /// DP line storage scalar for DTW-family kernels
    pub precision: Precision,
}

impl Default for ScanTuning {
    fn default() -> Self {
        Self { lanes: 1, precision: Precision::F64 }
    }
}

impl ScanTuning {
    /// Parse a `--lanes` CLI value: clamped into `1..=MAX_LANES` by the
    /// packer, 0 treated as "off" (1).
    pub fn with_lanes(mut self, lanes: usize) -> Self {
        self.lanes = lanes.max(1);
        self
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// Everything derived from one (query, window) pair, reusable across scans
/// and shards: the z-normalised query, its sorted order, envelopes, and
/// all work buffers.
#[derive(Debug, Clone)]
pub struct QueryContext {
    /// z-normalised query
    pub q: Vec<f64>,
    /// warping window in cells
    pub w: usize,
    /// indices of `q` by |value| descending
    pub order: Vec<usize>,
    /// q reordered by `order`
    qo: Vec<f64>,
    /// query envelopes reordered by `order`
    uo: Vec<f64>,
    lo: Vec<f64>,
    /// query envelopes in natural order — the strip scan's unordered
    /// chunked LB_Keogh pass reads these
    u: Vec<f64>,
    l: Vec<f64>,
    // work buffers
    cb1: Vec<f64>,
    cb2: Vec<f64>,
    cb_cum: Vec<f64>,
    zbuf: Vec<f64>,
    /// the one kernel workspace every metric's evaluation runs on — for
    /// cohort members it starts empty and a per-shard-worker pool is
    /// swapped in ([`QueryContext::swap_kernel_buffers`])
    ws: KernelWorkspace,
    /// SoA scratch lanes for the strip-mined scan (empty until first use)
    strip: StripScratch,
    /// projection + envelope scratch for LB_Improved's second pass (the
    /// per-candidate hot path builds one envelope per survivor, so the
    /// buffers and deques must persist across candidates)
    improved: ImprovedScratch,
    /// per-query cost-model tables (WDTW weights, ERP accumulators),
    /// prepared once at build time so per-candidate kernel dispatch
    /// borrows instead of reallocating
    cost_cache: CostModelCache,
    /// survivor lane packer for the multi-candidate wavefront kernel
    /// (width 1 — inert — unless [`QueryContext::with_tuning`] widens it)
    lanes: LanePacker,
    /// DP line storage width for DTW-family kernel calls (f64 default)
    precision: Precision,
    /// elastic metric every candidate is scored under
    pub metric: Metric,
}

impl QueryContext {
    /// Context for the default metric (banded DTW) — every pre-metric
    /// call site, bit-identical to the seed behaviour.
    pub fn new(query_raw: &[f64], w: usize) -> Self {
        Self::with_metric(query_raw, w, Metric::Cdtw)
    }

    /// Context for an arbitrary metric. `w` is re-derived through
    /// [`Metric::effective_window`] (DTW/WDTW are unbanded by
    /// convention), and the envelopes are built for that window.
    ///
    /// Panics on a query containing NaN (the sort-order build has no
    /// total order to offer it); serving layers validate first via
    /// [`QueryContext::try_with_metric`].
    pub fn with_metric(query_raw: &[f64], w: usize, metric: Metric) -> Self {
        Self::build(query_raw, w, metric, false)
    }

    /// Context for a **cohort** member: identical to
    /// [`QueryContext::with_metric`] except that the kernel workspace and
    /// the z-normalisation buffer start *empty* — the cohort scan swaps a
    /// per-shard-worker pool in before scoring survivors
    /// ([`crate::search::cohort::CohortPool`]), so allocating them here
    /// per query per shard would be pure waste. Safe to use outside a
    /// cohort too: the buffers grow on first kernel use.
    pub fn with_metric_pooled(query_raw: &[f64], w: usize, metric: Metric) -> Self {
        Self::build(query_raw, w, metric, true)
    }

    fn build(query_raw: &[f64], w: usize, metric: Metric, pooled: bool) -> Self {
        let q = znorm(query_raw);
        let n = q.len();
        let w = metric.effective_window(n, w);
        // envelopes, sort order and the reordered bounds only exist for
        // metrics whose cascade can use them — a bound-free metric would
        // pay the O(n log n) setup once per shard for nothing
        let (order, qo, uo, lo, u, l) = if metric.uses_envelopes() {
            let order = sort_order(&q);
            let mut u = Vec::new();
            let mut l = Vec::new();
            envelopes_into(&q, w, &mut u, &mut l);
            let uo = reorder(&u, &order);
            let lo = reorder(&l, &order);
            let qo = reorder(&q, &order);
            (order, qo, uo, lo, u, l)
        } else {
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new())
        };
        // build the metric's per-query tables up front: every candidate
        // evaluation then borrows them, and `cost_model_rebuilds` stays 0
        // for the whole scan
        let mut cost_cache = CostModelCache::new();
        cost_cache.prepare(metric, &q);
        Self {
            q,
            w,
            order,
            qo,
            uo,
            lo,
            u,
            l,
            cb1: vec![0.0; n],
            cb2: vec![0.0; n],
            cb_cum: vec![0.0; n + 1],
            zbuf: if pooled { Vec::new() } else { vec![0.0; n] },
            ws: if pooled { KernelWorkspace::default() } else { KernelWorkspace::with_capacity(n) },
            strip: StripScratch::default(),
            improved: ImprovedScratch::new(),
            cost_cache,
            lanes: LanePacker::default(),
            precision: Precision::F64,
            metric,
        }
    }

    /// Apply a [`ScanTuning`] to this context: configure the survivor
    /// lane packer and the DP storage precision. The default tuning is a
    /// no-op (scalar f64 — the bitwise-pinned path).
    pub fn with_tuning(mut self, tuning: ScanTuning) -> Self {
        self.precision = tuning.precision;
        self.lanes.configure(tuning.lanes, tuning.precision);
        if tuning.precision == Precision::F32 {
            // pre-size the context-side f32 lines without counting a
            // regrow, mirroring the f64 lines' build-time capacity
            self.ws.warm32(self.q.len());
        }
        self
    }

    /// Should this scan defer survivors into lane groups? Only the
    /// DTW-family metrics under an EAPruned suite core qualify: the lane
    /// kernel instantiates the uniform [`crate::distances::kernel::DtwCost`]
    /// model directly, which is exactly what those paths' scalar
    /// dispatch evaluates. Everything else keeps the scalar route even
    /// when lanes are configured.
    #[inline]
    fn lane_eligible(&self, suite: Suite) -> bool {
        self.lanes.width() >= 2 && self.metric.uses_envelopes() && suite.core_is_eap()
    }

    /// Swap the kernel workspace and z-buffer with a caller-owned pool —
    /// the cohort scan's per-shard-worker buffer reuse. Called in pairs
    /// (swap in, score survivors, swap out), so ownership always returns
    /// to the pool and capacity is amortised across every member of every
    /// cohort the worker serves.
    pub(crate) fn swap_kernel_buffers(&mut self, ws: &mut KernelWorkspace, zbuf: &mut Vec<f64>) {
        std::mem::swap(&mut self.ws, ws);
        std::mem::swap(&mut self.zbuf, zbuf);
    }

    /// The query envelopes in natural (unsorted) order — what the batched
    /// unordered LB_Keogh EQ pass consumes. Empty for metrics without
    /// envelope bounds.
    pub(crate) fn envelopes_natural(&self) -> (&[f64], &[f64]) {
        (&self.u, &self.l)
    }

    /// LB_Improved second-pass tail for one raw candidate window — what
    /// the batched strip/cohort improved stages call. Routes to
    /// [`lb_improved_tail_ec_raw`] with the context's persistent
    /// projection/envelope scratch; returns a partial (still admissible)
    /// sum as soon as the tail alone exceeds `budget`.
    pub(crate) fn improved_tail_raw(
        &mut self,
        du: &[f64],
        dl: &[f64],
        mean: f64,
        std: f64,
        window: &[f64],
        budget: f64,
    ) -> f64 {
        lb_improved_tail_ec_raw(
            &mut self.improved,
            &self.q,
            du,
            dl,
            mean,
            std,
            window,
            self.w,
            budget,
        )
    }

    /// Validating constructor: the graceful API boundary for
    /// client-controlled queries. A query containing NaN or ±inf — which
    /// would z-normalise to garbage and panic the sort-order build deep
    /// inside a shard worker — is rejected here with an error instead.
    pub fn try_with_metric(query_raw: &[f64], w: usize, metric: Metric) -> Result<Self> {
        validate_series("query", query_raw)?;
        Ok(Self::with_metric(query_raw, w, metric))
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// Reject series containing NaN/±inf with a positioned error — the shared
/// validation every serving boundary (engine, service, wire protocol)
/// routes through, so malformed floats never reach the scan's sort-order
/// build or poison a shard worker's heap.
pub fn validate_series(what: &str, s: &[f64]) -> Result<()> {
    if let Some(i) = s.iter().position(|v| !v.is_finite()) {
        anyhow::bail!("{what} contains a non-finite value at index {i} ({})", s[i]);
    }
    Ok(())
}

/// Envelopes of the *raw* reference stream for one window size — computed
/// once per (reference, w) and shared by every query and shard (LB_Keogh
/// EC z-normalises them per candidate on the fly).
#[derive(Debug, Clone)]
pub struct DataEnvelopes {
    pub upper: Vec<f64>,
    pub lower: Vec<f64>,
}

impl DataEnvelopes {
    pub fn new(reference: &[f64], w: usize) -> Self {
        let mut upper = Vec::new();
        let mut lower = Vec::new();
        envelopes_into(reference, w, &mut upper, &mut lower);
        Self { upper, lower }
    }

    /// The (upper, lower) envelope strip for one candidate window of `n`
    /// points starting at `pos`.
    #[inline]
    pub fn strip(&self, pos: usize, n: usize) -> (&[f64], &[f64]) {
        (&self.upper[pos..pos + n], &self.lower[pos..pos + n])
    }
}

/// Where a scan gets candidate window statistics from.
#[derive(Debug, Clone, Copy)]
pub enum ScanStats<'a> {
    /// The seed behaviour: one streaming [`WindowStats`] recurrence,
    /// started fresh at the scan's first position.
    Streaming,
    /// A precomputed per-position table shared read-only across queries
    /// and shards ([`crate::index::ref_index::RefIndex::stats_for`]).
    /// Positions index the *full* reference, so every shard sees stats
    /// bit-identical to a full from-zero streaming scan.
    Indexed(&'a BucketStats),
}

/// Scan candidate start positions `[start, end)` of `reference`, beginning
/// from upper bound `bsf` (pass `+inf` for a fresh search). Returns the
/// best match found *below* `bsf` (ties keep the earlier position), or
/// `None` if nothing beat it.
#[allow(clippy::too_many_arguments)]
pub fn scan(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    bsf: f64,
    counters: &mut Counters,
) -> Option<Match> {
    scan_policy(reference, start, end, ctx, denv, suite, suite.cascade(), bsf, counters)
}

/// [`scan`] with an explicit cascade policy (the ablation entry point:
/// any DTW core × any subset of the lower-bound cascade). A thin k = 1
/// wrapper over [`scan_topk_policy`].
#[allow(clippy::too_many_arguments)]
pub fn scan_policy(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    cascade: CascadePolicy,
    bsf: f64,
    counters: &mut Counters,
) -> Option<Match> {
    let mut topk = TopK::with_bound(1, bsf);
    scan_topk_policy(
        reference,
        start,
        end,
        ctx,
        denv,
        ScanStats::Streaming,
        suite,
        cascade,
        &mut topk,
        counters,
    );
    topk.into_sorted().into_iter().next()
}

/// Scan `[start, end)` collecting the top-k matches into `topk` (whose
/// current k-th best / external bound is the early-abandon threshold).
/// This is the shard worker's inner loop; everything scalar-best-so-far
/// in the seed is the `k = 1` case of this function.
#[allow(clippy::too_many_arguments)]
pub fn scan_topk_policy(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: ScanStats<'_>,
    suite: Suite,
    cascade: CascadePolicy,
    topk: &mut TopK,
    counters: &mut Counters,
) {
    scan_topk_scalar(
        reference,
        start,
        end,
        ctx,
        denv,
        stats,
        suite,
        cascade,
        topk,
        counters,
        ScanObs::OFF,
    );
}

/// [`scan_topk_policy`] with an observability handle — the scalar scan
/// body. Recording is write-only: an attached [`ScanObs`] cell changes no
/// result bit, and the `OFF` handle reads no clocks.
#[allow(clippy::too_many_arguments)]
fn scan_topk_scalar(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: ScanStats<'_>,
    suite: Suite,
    cascade: CascadePolicy,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    let n = ctx.len();
    assert!(n > 0, "empty query");
    assert!(reference.len() >= n, "reference shorter than query");
    let end = end.min(reference.len() - n + 1);
    if start >= end {
        return;
    }
    // metrics outside the DTW family have no valid envelope bounds: the
    // scan degrades to the bound-free EAPruned path, still threshold-driven
    // through the top-k collector
    let cascade = if ctx.metric.uses_envelopes() { cascade } else { CascadePolicy::none() };
    debug_assert!(
        !cascade.needs_data_envelopes() || denv.is_some(),
        "suite {:?} needs data envelopes",
        suite
    );
    match stats {
        ScanStats::Streaming => {
            let mut ws = WindowStats::new(&reference[start..], n);
            loop {
                let pos = start + ws.pos();
                let window = ws.window();
                let (mean, std) = ws.mean_std();
                eval_candidate(
                    pos, window, mean, std, ctx, denv, suite, cascade, false, topk, counters, obs,
                );
                if pos + 1 >= end || !ws.advance() {
                    break;
                }
            }
        }
        ScanStats::Indexed(table) => {
            debug_assert_eq!(table.qlen(), n, "stats bucket / query length mismatch");
            for pos in start..end {
                let window = &reference[pos..pos + n];
                let (mean, std) = table.mean_std(pos);
                eval_candidate(
                    pos, window, mean, std, ctx, denv, suite, cascade, true, topk, counters, obs,
                );
            }
        }
    }
}

/// [`scan_topk_policy`] with an explicit [`ScanMode`]: `Scalar` is the
/// seed's per-candidate loop verbatim, `Strip` the strip-mined pipeline.
/// Both return bitwise-identical top-k contents.
#[allow(clippy::too_many_arguments)]
pub fn scan_topk_policy_mode(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: ScanStats<'_>,
    suite: Suite,
    cascade: CascadePolicy,
    mode: ScanMode,
    topk: &mut TopK,
    counters: &mut Counters,
) {
    scan_topk_policy_mode_obs(
        reference,
        start,
        end,
        ctx,
        denv,
        stats,
        suite,
        cascade,
        mode,
        topk,
        counters,
        ScanObs::OFF,
    );
}

/// [`scan_topk_policy_mode`] with an observability handle — what shard
/// workers call so stage latencies land in their registry cell. An
/// attached cell is write-only (results stay bitwise identical to
/// [`ScanObs::OFF`], pinned by `obs_attached_scan_is_bitwise_identical`).
#[allow(clippy::too_many_arguments)]
pub fn scan_topk_policy_mode_obs(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: ScanStats<'_>,
    suite: Suite,
    cascade: CascadePolicy,
    mode: ScanMode,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    match mode {
        ScanMode::Scalar => scan_topk_scalar(
            reference, start, end, ctx, denv, stats, suite, cascade, topk, counters, obs,
        ),
        ScanMode::Strip => scan_topk_strips(
            reference, start, end, ctx, denv, stats, suite, cascade, topk, counters, obs,
        ),
    }
}

/// The strip-mined scan: candidate positions `[start, end)` in strips of
/// [`DEFAULT_STRIP`].
///
/// Per strip: (1) the window statistics of every lane are pulled into SoA
/// scratch in one pass (a [`BucketStats::strip`] view, or the streaming
/// recurrence advanced across the strip — both bit-compatible with the
/// scalar scan); (2) batched LB_Kim, the unordered chunked LB_Keogh EQ
/// pass, and the batched LB_Improved stage (unordered EC first pass plus
/// the role-swapped second pass over the shared data envelopes) filter the
/// whole strip against the strip-entry threshold;
/// (3) survivors are evaluated in **ascending-lower-bound order**, so the
/// early winners tighten the top-k threshold before their strip-mates are
/// scored — measurably cutting full-DTW calls — with a fresh threshold
/// and a single-pass z-normalisation feeding both the sorted
/// `cb`-producing LB_Keogh pass and the distance kernel.
#[allow(clippy::too_many_arguments)]
fn scan_topk_strips(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: ScanStats<'_>,
    suite: Suite,
    cascade: CascadePolicy,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    let n = ctx.len();
    assert!(n > 0, "empty query");
    assert!(reference.len() >= n, "reference shorter than query");
    let end = end.min(reference.len() - n + 1);
    if start >= end {
        return;
    }
    let cascade = if ctx.metric.uses_envelopes() { cascade } else { CascadePolicy::none() };
    debug_assert!(
        !cascade.needs_data_envelopes() || denv.is_some(),
        "suite {:?} needs data envelopes",
        suite
    );
    let indexed = matches!(stats, ScanStats::Indexed(_));
    // one streaming recurrence shared by every strip of this scan — the
    // same state a scalar streaming scan would carry
    let mut ws = match stats {
        ScanStats::Streaming => Some(WindowStats::new(&reference[start..], n)),
        ScanStats::Indexed(table) => {
            debug_assert_eq!(table.qlen(), n, "stats bucket / query length mismatch");
            None
        }
    };
    let mut scratch = std::mem::take(&mut ctx.strip);
    let mut strip_start = start;
    while strip_start < end {
        // fault site: the conformance_faults suite arms this to model a
        // slow scan; the default build compiles it to nothing
        crate::fault::fire_stall(crate::fault::STRIP_STALL);
        let len = (end - strip_start).min(DEFAULT_STRIP);
        scratch.reset(len);
        match (&mut ws, stats) {
            (Some(ws), _) => {
                for i in 0..len {
                    debug_assert_eq!(start + ws.pos(), strip_start + i);
                    let (m, s) = ws.mean_std();
                    scratch.mean[i] = m;
                    scratch.std[i] = s;
                    if strip_start + i + 1 < end {
                        ws.advance();
                    }
                }
            }
            (None, ScanStats::Indexed(table)) => {
                let (mean, std) = table.strip(strip_start, len);
                scratch.mean.copy_from_slice(mean);
                scratch.std.copy_from_slice(std);
            }
            (None, ScanStats::Streaming) => unreachable!("streaming scan carries its recurrence"),
        }
        counters.strip_batches += 1;
        counters.candidates += len as u64;
        // constant for the batch stages, like the scalar loop's bsf is
        // constant for one candidate
        let bsf_strip = topk.threshold();
        if cascade.kim {
            let t0 = obs.now();
            batch_lb_kim_into(
                &ctx.q,
                reference,
                strip_start,
                len,
                &scratch.mean,
                &scratch.std,
                &mut scratch.lb,
            );
            for i in 0..len {
                if scratch.lb[i] > bsf_strip {
                    scratch.alive[i] = false;
                    counters.lb_kim_prunes += 1;
                    counters.batch_lb_prunes += 1;
                }
            }
            obs.stage_since(Stage::BoundKim, t0);
        }
        if cascade.keogh_eq {
            let t0 = obs.now();
            for i in 0..len {
                if !scratch.alive[i] {
                    continue;
                }
                let pos = strip_start + i;
                let lb = lb_keogh_eq_unordered(
                    &ctx.u,
                    &ctx.l,
                    &reference[pos..pos + n],
                    scratch.mean[i],
                    scratch.std[i],
                );
                if lb > scratch.lb[i] {
                    scratch.lb[i] = lb;
                }
                // the unordered sum adds the scalar pass's exact terms in
                // a different order, so it can sit ~n·ε relative above the
                // sorted value; discount it by far more than that bound
                // before pruning, so this batch stage can never prune a
                // candidate the scalar cascade would keep (survivors are
                // re-checked with the exact sorted pass anyway)
                if lb * (1.0 - 1e-9) > bsf_strip {
                    scratch.alive[i] = false;
                    counters.lb_keogh_eq_prunes += 1;
                    counters.batch_lb_prunes += 1;
                }
            }
            obs.stage_since(Stage::BoundKeoghEq, t0);
        }
        if cascade.improved {
            // batched LB_Improved: an unordered EC first pass over the
            // shared data envelopes, then the role-swapped second pass —
            // so strips prune what survives EQ without waiting for the
            // per-survivor sorted passes. Same ε discount as the EQ stage
            // (the unordered sums add the scalar passes' exact terms in a
            // different order), so no candidate the scalar cascade keeps
            // can be dropped; survivors are re-checked exactly anyway.
            let denv = denv.expect("data envelopes required");
            let t0 = obs.now();
            for i in 0..len {
                if !scratch.alive[i] {
                    continue;
                }
                let pos = strip_start + i;
                let (du, dl) = denv.strip(pos, n);
                let mut base = 0.0;
                if cascade.keogh_ec {
                    let ec =
                        lb_keogh_ec_unordered(&ctx.q, du, dl, scratch.mean[i], scratch.std[i]);
                    if ec * (1.0 - 1e-9) > bsf_strip {
                        scratch.alive[i] = false;
                        counters.lb_keogh_ec_prunes += 1;
                        counters.batch_lb_prunes += 1;
                        continue;
                    }
                    base = ec;
                }
                let tail = lb_improved_tail_ec_raw(
                    &mut ctx.improved,
                    &ctx.q,
                    du,
                    dl,
                    scratch.mean[i],
                    scratch.std[i],
                    &reference[pos..pos + n],
                    ctx.w,
                    bsf_strip - base,
                );
                let lb = base + tail;
                if lb * (1.0 - 1e-9) > bsf_strip {
                    scratch.alive[i] = false;
                    counters.lb_improved_prunes += 1;
                    counters.batch_lb_prunes += 1;
                    continue;
                }
                if lb > scratch.lb[i] {
                    scratch.lb[i] = lb;
                }
            }
            obs.stage_since(Stage::BoundImproved, t0);
        }
        scratch.order_survivors();
        obs.record_dist(DistKind::StripSurvivors, scratch.order.len() as u64);
        for &i in &scratch.order {
            let i = i as usize;
            let pos = strip_start + i;
            eval_survivor(
                pos,
                &reference[pos..pos + n],
                scratch.mean[i],
                scratch.std[i],
                bsf_strip,
                ctx,
                denv,
                suite,
                cascade,
                indexed,
                topk,
                counters,
                obs,
            );
        }
        // lane groups never span strips: a partially-filled group is
        // flushed here (a single pending lane takes the scalar kernel)
        flush_lane_group(ctx, topk, counters, obs);
        strip_start += len;
    }
    ctx.strip = scratch;
}

/// One batch-bound survivor through the per-candidate tail of the strip
/// pipeline: fresh threshold, single-pass z-normalisation shared by the
/// sorted (`cb`-producing) LB_Keogh pass and the kernel, then LB_Keogh EC
/// and the metric's kernel exactly as the scalar loop runs them. All
/// distance math is IEEE-identical to [`eval_candidate`]'s; `bsf_strip`
/// (the strip-entry threshold) only attributes prunes that the
/// within-strip LB-ordered tightening made possible.
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_survivor(
    pos: usize,
    window: &[f64],
    mean: f64,
    std: f64,
    bsf_strip: f64,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    cascade: CascadePolicy,
    indexed: bool,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    let n = ctx.len();
    let bsf = topk.threshold();
    // single-pass z-normalisation: the scalar path normalises the window
    // inside LB_Keogh EQ and then *again* into zbuf for the kernel; here
    // zbuf is filled once and both consumers read it
    ctx.zbuf.clear();
    ctx.zbuf.extend(window.iter().map(|&x| znorm_point(x, mean, std)));
    let mut lb1 = 0.0;
    if cascade.keogh_eq {
        let t0 = obs.now();
        lb1 = lb_keogh_eq_pre(&ctx.order, &ctx.uo, &ctx.lo, &ctx.zbuf, bsf, &mut ctx.cb1);
        obs.stage_since(Stage::BoundKeoghEq, t0);
        if lb1 > bsf {
            counters.lb_keogh_eq_prunes += 1;
            if lb1 <= bsf_strip {
                counters.lb_order_saved_dtw_calls += 1;
            }
            return;
        }
    }
    let mut lb2 = 0.0;
    let mut have2 = false;
    if cascade.keogh_ec {
        let denv = denv.expect("data envelopes required");
        let (u, l) = denv.strip(pos, n);
        let t0 = obs.now();
        lb2 = lb_keogh_ec(&ctx.order, &ctx.qo, u, l, mean, std, bsf, &mut ctx.cb2);
        obs.stage_since(Stage::BoundKeoghEc, t0);
        have2 = true;
        if lb2 > bsf {
            counters.lb_keogh_ec_prunes += 1;
            if indexed {
                counters.index_ec_prunes += 1;
            }
            if lb2 <= bsf_strip {
                counters.lb_order_saved_dtw_calls += 1;
            }
            return;
        }
    }
    if cascade.improved {
        // same second pass as the scalar loop, reading the already-filled
        // z-norm buffer instead of re-normalising the raw window — the
        // per-point values are IEEE-identical either way
        let denv = denv.expect("data envelopes required");
        let (du, dl) = denv.strip(pos, n);
        let t0 = obs.now();
        let tail = lb_improved_tail_ec(
            &mut ctx.improved,
            &ctx.q,
            du,
            dl,
            mean,
            std,
            &ctx.zbuf,
            ctx.w,
            bsf - lb2,
        );
        obs.stage_since(Stage::BoundImproved, t0);
        if lb2 + tail > bsf {
            counters.lb_improved_prunes += 1;
            if lb2 + tail <= bsf_strip {
                counters.lb_order_saved_dtw_calls += 1;
            }
            return;
        }
    }
    if ctx.lane_eligible(suite) {
        defer_survivor(pos, lb1, lb2, have2, bsf, ctx, cascade, topk, counters, obs);
        return;
    }
    score_candidate(pos, lb1, lb2, have2, bsf, ctx, suite, cascade, topk, counters, obs);
}

/// Defer one cascade survivor into the context's lane packer instead of
/// scoring it immediately: the z-normalised window, the same
/// cumulative-bound tail [`score_candidate`] would have used, and the
/// current threshold are copied into the next free lane. A full group is
/// flushed on the spot; a partial one waits for its strip's survivor
/// list to end ([`flush_lane_group`] at the strip boundary).
///
/// Deferral never changes the final top-k *contents*: thresholds frozen
/// at pack time are only ever looser than sequential evaluation's, so a
/// deferred lane can over-admit (complete where sequential would have
/// abandoned) but never over-prune, and every completed distance is
/// bitwise the scalar kernel's.
#[allow(clippy::too_many_arguments)]
fn defer_survivor(
    pos: usize,
    lb1: f64,
    lb2: f64,
    have2: bool,
    bsf: f64,
    ctx: &mut QueryContext,
    cascade: CascadePolicy,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    let full = {
        // same tighter-Keogh selection as score_candidate
        let cb = if cascade.tighten && (cascade.keogh_eq || have2) {
            let src = if have2 && lb2 > lb1 { &ctx.cb2 } else { &ctx.cb1 };
            cumulate_bound(src, &mut ctx.cb_cum);
            Some(ctx.cb_cum.as_slice())
        } else {
            None
        };
        ctx.lanes.push(pos, &ctx.zbuf, cb, bsf)
    };
    if full {
        flush_lane_group(ctx, topk, counters, obs);
    }
}

/// Evaluate and drain the context's pending lane group: refresh every
/// lane's threshold from the owner's [`TopK`], run the wavefront kernel
/// (or the scalar kernel for a lone survivor), then account each lane
/// exactly as a scalar evaluation would — one metric call + outcome per
/// lane, so `dtw_calls == dtw_abandons + dtw_completions` folds the
/// multi-lane path in unchanged — plus the lane-packing counters and the
/// `lane_occupancy` histogram for groups of two or more.
pub(crate) fn flush_lane_group(
    ctx: &mut QueryContext,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    let pending = ctx.lanes.lanes_pending();
    if pending == 0 {
        return;
    }
    let metric = ctx.metric;
    let t0 = obs.now();
    {
        let QueryContext { q, w, lanes, .. } = ctx;
        lanes.eval(q, *w, topk.threshold());
    }
    obs.stage_since(Stage::KernelEval, t0);
    let mut lane_abandons = 0u64;
    for k in 0..pending {
        let (pos, e) = ctx.lanes.result(k);
        counters.record_metric_call(metric);
        counters.record_metric_outcome(metric, e.abandoned);
        if e.abandoned {
            lane_abandons += 1;
        }
        if !e.abandoned && e.dist.is_finite() && topk.offer(Match { pos, dist: e.dist }) {
            counters.topk_updates += 1;
            counters.ub_updates += 1;
        }
    }
    if pending >= 2 {
        counters.kernel_multi_calls += 1;
        counters.kernel_lanes_filled += pending as u64;
        counters.kernel_lane_abandons += lane_abandons;
        obs.record_dist(DistKind::LaneOccupancy, pending as u64);
    }
    ctx.lanes.clear();
}

/// One candidate through cascade + DTW core + collector. `indexed` marks
/// stats/envelopes as coming from the shared reference index, so its
/// pruning power is attributed separately in the counters.
#[allow(clippy::too_many_arguments)]
fn eval_candidate(
    pos: usize,
    window: &[f64],
    mean: f64,
    std: f64,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    cascade: CascadePolicy,
    indexed: bool,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    let n = ctx.len();
    counters.candidates += 1;
    // constant for the whole candidate, exactly like the scalar loop's bsf
    let bsf = topk.threshold();
    if cascade.kim {
        let t0 = obs.now();
        let lb = lb_kim_hierarchy(&ctx.q, window, mean, std, bsf);
        obs.stage_since(Stage::BoundKim, t0);
        if lb > bsf {
            counters.lb_kim_prunes += 1;
            return;
        }
    }
    let mut lb1 = 0.0;
    if cascade.keogh_eq {
        let t0 = obs.now();
        lb1 = lb_keogh_eq(&ctx.order, &ctx.uo, &ctx.lo, window, mean, std, bsf, &mut ctx.cb1);
        obs.stage_since(Stage::BoundKeoghEq, t0);
        if lb1 > bsf {
            counters.lb_keogh_eq_prunes += 1;
            return;
        }
    }
    let mut lb2 = 0.0;
    let mut have2 = false;
    if cascade.keogh_ec {
        let denv = denv.expect("data envelopes required");
        let t0 = obs.now();
        lb2 = lb_keogh_ec(
            &ctx.order,
            &ctx.qo,
            &denv.upper[pos..pos + n],
            &denv.lower[pos..pos + n],
            mean,
            std,
            bsf,
            &mut ctx.cb2,
        );
        obs.stage_since(Stage::BoundKeoghEc, t0);
        have2 = true;
        if lb2 > bsf {
            counters.lb_keogh_ec_prunes += 1;
            if indexed {
                counters.index_ec_prunes += 1;
            }
            return;
        }
    }
    if cascade.improved {
        // LB_Improved's second pass: project q onto the candidate's
        // envelope and run a role-swapped Keogh pass, adding onto the
        // first-pass EC sum (0 if the EC stage is off — the tail alone is
        // admissible too). The tail's contributions are *not* fed into the
        // cb tightening arrays: they are indexed by candidate positions,
        // not the query rows the kernel abandons on.
        let denv = denv.expect("data envelopes required");
        let t0 = obs.now();
        let tail = lb_improved_tail_ec_raw(
            &mut ctx.improved,
            &ctx.q,
            &denv.upper[pos..pos + n],
            &denv.lower[pos..pos + n],
            mean,
            std,
            window,
            ctx.w,
            bsf - lb2,
        );
        obs.stage_since(Stage::BoundImproved, t0);
        if lb2 + tail > bsf {
            counters.lb_improved_prunes += 1;
            return;
        }
    }
    // z-normalise the candidate for the kernel (the cb selection below
    // never touches zbuf, so filling it first is order-equivalent)
    ctx.zbuf.clear();
    ctx.zbuf.extend(window.iter().map(|&x| znorm_point(x, mean, std)));
    score_candidate(pos, lb1, lb2, have2, bsf, ctx, suite, cascade, topk, counters, obs);
}

/// Shared final stage of both scan front-ends: pick the tighter Keogh
/// contribution array, cumulate it into the DTW tightening tail, run the
/// metric's kernel (the suite's DTW core for the DTW family, the
/// generalised EAPruned elsewhere) on the already z-normalised window in
/// `ctx.zbuf`, and offer the result. [`eval_candidate`] and
/// [`eval_survivor`] both end here with identical inputs — one body, so
/// the two paths cannot drift apart.
#[allow(clippy::too_many_arguments)]
fn score_candidate(
    pos: usize,
    lb1: f64,
    lb2: f64,
    have2: bool,
    bsf: f64,
    ctx: &mut QueryContext,
    suite: Suite,
    cascade: CascadePolicy,
    topk: &mut TopK,
    counters: &mut Counters,
    obs: ScanObs<'_>,
) {
    // cumulative tail from the tighter of the two Keogh bounds
    let cb = if cascade.tighten && (cascade.keogh_eq || have2) {
        let src = if have2 && lb2 > lb1 { &ctx.cb2 } else { &ctx.cb1 };
        cumulate_bound(src, &mut ctx.cb_cum);
        Some(ctx.cb_cum.as_slice())
    } else {
        None
    };
    let metric = ctx.metric;
    counters.record_metric_call(metric);
    // the unified kernel reports abandons itself, so the per-metric
    // attribution is exact rather than inferred from an infinite return
    // (an infeasible band — impossible here, windows match the query
    // length — would not be an abandon)
    let t0 = obs.now();
    // opt-in f32 DP lines take the dedicated entry point on the exact
    // route (DTW-family metric, EAPruned core) the lane path covers;
    // everything else keeps the f64 dispatch verbatim
    let out = if ctx.precision == Precision::F32 && metric.uses_envelopes() && suite.core_is_eap() {
        eap_cdtw_eval_f32(&ctx.q, &ctx.zbuf, ctx.w, bsf, cb, &mut ctx.ws)
    } else {
        metric.eval_outcome_cached(
            &ctx.q,
            &ctx.zbuf,
            ctx.w,
            bsf,
            cb,
            suite,
            &mut ctx.ws,
            &mut ctx.cost_cache,
        )
    };
    obs.stage_since(Stage::KernelEval, t0);
    counters.cost_model_rebuilds += ctx.cost_cache.take_rebuilds();
    counters.record_metric_outcome(metric, out.abandoned);
    if !out.abandoned && out.dist.is_finite() && topk.offer(Match { pos, dist: out.dist }) {
        counters.topk_updates += 1;
        counters.ub_updates += 1;
    }
}

/// Full-stream similarity search: the paper's §5 task. Locates the closest
/// z-normalised subsequence of `reference` to `query_raw` under windowed
/// DTW with window `w` (cells).
pub fn search_subsequence(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Match {
    let mut ctx = QueryContext::new(query_raw, w);
    let denv = suite
        .cascade()
        .needs_data_envelopes()
        .then(|| DataEnvelopes::new(reference, w));
    scan(
        reference,
        0,
        reference.len() - ctx.len() + 1,
        &mut ctx,
        denv.as_ref(),
        suite,
        f64::INFINITY,
        counters,
    )
    .expect("fresh search always finds a best match")
}

/// Top-k variant of [`search_subsequence`]: the k closest candidate
/// windows in ascending `(dist, pos)` order (fewer if the reference has
/// fewer than k candidate positions). `k = 1` reproduces
/// [`search_subsequence`] exactly.
pub fn search_subsequence_topk(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    k: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Vec<Match> {
    search_subsequence_topk_metric(reference, query_raw, w, k, Metric::Cdtw, suite, counters)
}

/// Metric-generic top-k subsequence search: the k closest candidate
/// windows of `reference` to the z-normalised query under `metric`,
/// ascending `(dist, pos)`.
///
/// DTW-family metrics keep the full z-norm + envelope cascade fast path;
/// ERP/MSM/TWE/WDTW run the bound-free EAPruned scan, still
/// threshold-driven through the [`TopK`] collector. Degenerate inputs
/// degrade gracefully: a query longer than the reference (zero candidate
/// windows) or `k = 0` returns an empty list, and `k` larger than the
/// candidate count returns every window ranked. Metric parameters are
/// assumed valid ([`Metric::validate`]) — the serving layer validates
/// wire and engine input before reaching this loop.
pub fn search_subsequence_topk_metric(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    k: usize,
    metric: Metric,
    suite: Suite,
    counters: &mut Counters,
) -> Vec<Match> {
    search_subsequence_topk_metric_mode(
        reference,
        query_raw,
        w,
        k,
        metric,
        suite,
        ScanMode::Scalar,
        counters,
    )
}

/// [`search_subsequence_topk_metric`] with an explicit [`ScanMode`] —
/// the A/B entry point `benches/strip_throughput.rs` and the conformance
/// suite drive. The two modes return bitwise-identical results; `Strip`
/// reaches fewer full-DTW calls via batch bounds + LB-ordered evaluation.
#[allow(clippy::too_many_arguments)]
pub fn search_subsequence_topk_metric_mode(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    k: usize,
    metric: Metric,
    suite: Suite,
    mode: ScanMode,
    counters: &mut Counters,
) -> Vec<Match> {
    let mut ctx = QueryContext::with_metric(query_raw, w, metric);
    if k == 0 || ctx.is_empty() || reference.len() < ctx.len() {
        return Vec::new();
    }
    let denv = metric
        .wants_data_envelopes(suite)
        .then(|| DataEnvelopes::new(reference, ctx.w));
    let mut topk = TopK::new(k);
    scan_topk_policy_mode(
        reference,
        0,
        reference.len() - ctx.len() + 1,
        &mut ctx,
        denv.as_ref(),
        ScanStats::Streaming,
        suite,
        suite.cascade(),
        mode,
        &mut topk,
        counters,
    );
    topk.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::distances::DtwWorkspace;

    /// Brute force oracle: exact banded DTW at every position.
    fn brute(reference: &[f64], query_raw: &[f64], w: usize) -> Match {
        let q = znorm(query_raw);
        let n = q.len();
        let mut best = Match { pos: 0, dist: f64::INFINITY };
        let mut ws = DtwWorkspace::default();
        for pos in 0..=(reference.len() - n) {
            let z = znorm(&reference[pos..pos + n]);
            let d = crate::distances::dtw::cdtw_ws(&q, &z, w, &mut ws);
            if d < best.dist {
                best = Match { pos, dist: d };
            }
        }
        best
    }

    fn small_workload() -> (Vec<f64>, Vec<f64>) {
        let r = Dataset::Ecg.generate(3000, 21);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 99).remove(0);
        (r, q)
    }

    #[test]
    fn all_suites_agree_with_brute_force() {
        let (r, q) = small_workload();
        for w_ratio in [0.1, 0.3] {
            let w = window_cells(q.len(), w_ratio);
            let want = brute(&r, &q, w);
            for suite in Suite::ALL {
                let mut c = Counters::new();
                let got = search_subsequence(&r, &q, w, suite, &mut c);
                assert_eq!(got.pos, want.pos, "{} w={w}", suite.name());
                assert!(
                    (got.dist - want.dist).abs() < 1e-9,
                    "{} w={w}: {} vs {}",
                    suite.name(),
                    got.dist,
                    want.dist
                );
                assert_eq!(c.candidates, (r.len() - q.len() + 1) as u64);
            }
        }
    }

    #[test]
    fn cascade_actually_prunes() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let mut c = Counters::new();
        search_subsequence(&r, &q, w, Suite::UcrMon, &mut c);
        assert!(
            c.lb_kim_prunes + c.lb_keogh_eq_prunes + c.lb_keogh_ec_prunes > 0,
            "{c:?}"
        );
        assert!(c.dtw_calls < c.candidates);
    }

    #[test]
    fn nolb_reaches_dtw_everywhere() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let mut c = Counters::new();
        search_subsequence(&r, &q, w, Suite::UcrMonNoLb, &mut c);
        assert_eq!(c.dtw_calls, c.candidates, "nolb is 100% DTW (Fig. 5 note)");
        assert!(c.dtw_abandons > 0, "EAP must abandon most candidates");
    }

    #[test]
    fn sharded_scan_equals_full_scan() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.2);
        let suite = Suite::UcrMon;
        let mut c = Counters::new();
        let full = search_subsequence(&r, &q, w, suite, &mut c);
        // two shards sharing the bsf sequentially
        let denv = DataEnvelopes::new(&r, w);
        let mut ctx = QueryContext::new(&q, w);
        let mid = r.len() / 2;
        let mut c1 = Counters::new();
        let m1 = scan(&r, 0, mid, &mut ctx, Some(&denv), suite, f64::INFINITY, &mut c1);
        let bsf = m1.map_or(f64::INFINITY, |m| m.dist);
        let m2 = scan(
            &r,
            mid,
            r.len() - q.len() + 1,
            &mut ctx,
            Some(&denv),
            suite,
            bsf,
            &mut c1,
        );
        let best = match (m1, m2) {
            (Some(a), Some(b)) => {
                if b.dist < a.dist {
                    b
                } else {
                    a
                }
            }
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (None, None) => panic!("no match"),
        };
        assert_eq!(best.pos, full.pos);
        assert!((best.dist - full.dist).abs() < 1e-9);
    }

    #[test]
    fn topk_k1_equals_best_so_far_search() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.2);
        for suite in Suite::ALL {
            let mut c1 = Counters::new();
            let want = search_subsequence(&r, &q, w, suite, &mut c1);
            let mut c2 = Counters::new();
            let got = search_subsequence_topk(&r, &q, w, 1, suite, &mut c2);
            assert_eq!(got, vec![want], "{}", suite.name());
            assert_eq!(c1.dtw_calls, c2.dtw_calls, "{}", suite.name());
        }
    }

    #[test]
    fn topk_is_sorted_prefix_of_brute_force_ranking() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let k = 5;
        let mut c = Counters::new();
        let got = search_subsequence_topk(&r, &q, w, k, Suite::UcrMon, &mut c);
        assert_eq!(got.len(), k);
        // brute-force ranking by (dist, pos)
        let qz = znorm(&q);
        let mut ws = DtwWorkspace::default();
        let mut all: Vec<Match> = (0..=(r.len() - q.len()))
            .map(|pos| {
                let z = znorm(&r[pos..pos + q.len()]);
                Match { pos, dist: crate::distances::dtw::cdtw_ws(&qz, &z, w, &mut ws) }
            })
            .collect();
        all.sort_by(|a, b| {
            a.dist.partial_cmp(&b.dist).unwrap().then(a.pos.cmp(&b.pos))
        });
        for (i, (g, want)) in got.iter().zip(&all).enumerate() {
            assert_eq!(g.pos, want.pos, "rank {i}");
            assert!((g.dist - want.dist).abs() < 1e-9, "rank {i}");
        }
        assert!(c.topk_updates >= k as u64);
    }

    #[test]
    fn indexed_stats_scan_matches_streaming_scan() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let table = crate::index::ref_index::BucketStats::build(&r, q.len());
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - q.len() + 1;
        for suite in [Suite::UcrMon, Suite::UcrMonNoLb] {
            let mut ctx = QueryContext::new(&q, w);
            let mut topk = TopK::new(3);
            let mut c = Counters::new();
            scan_topk_policy(
                &r,
                0,
                total,
                &mut ctx,
                Some(&denv),
                ScanStats::Indexed(&table),
                suite,
                suite.cascade(),
                &mut topk,
                &mut c,
            );
            let mut ctx2 = QueryContext::new(&q, w);
            let mut topk2 = TopK::new(3);
            let mut c2 = Counters::new();
            scan_topk_policy(
                &r,
                0,
                total,
                &mut ctx2,
                Some(&denv),
                ScanStats::Streaming,
                suite,
                suite.cascade(),
                &mut topk2,
                &mut c2,
            );
            // the table is built with the same recurrence the streaming
            // scan uses, so the two paths are bit-identical from pos 0
            assert_eq!(topk.into_sorted(), topk2.into_sorted(), "{}", suite.name());
            assert_eq!(c.candidates, c2.candidates);
            if suite.cascade().keogh_ec {
                assert_eq!(c.index_ec_prunes, c.lb_keogh_ec_prunes);
                assert_eq!(c2.index_ec_prunes, 0);
            }
        }
    }

    #[test]
    fn metric_scan_agrees_with_per_window_oracle() {
        let r = Dataset::Soccer.generate(800, 33);
        let q = crate::data::extract_queries(&r, 1, 48, 0.1, 34).remove(0);
        let w = 5;
        for metric in Metric::all_default() {
            let mut c = Counters::new();
            let got = search_subsequence_topk_metric(&r, &q, w, 1, metric, Suite::UcrMon, &mut c);
            assert_eq!(got.len(), 1, "{}", metric.name());
            // brute force with the metric's naive oracle
            let qz = znorm(&q);
            let weff = metric.effective_window(qz.len(), w);
            let mut best = Match { pos: 0, dist: f64::INFINITY };
            for pos in 0..=(r.len() - q.len()) {
                let cz = znorm(&r[pos..pos + q.len()]);
                let d = metric.exact(&qz, &cz, weff);
                if d < best.dist {
                    best = Match { pos, dist: d };
                }
            }
            assert_eq!(got[0].pos, best.pos, "{}", metric.name());
            assert!((got[0].dist - best.dist).abs() < 1e-9, "{}", metric.name());
            // every candidate hit the kernel of the right metric
            assert_eq!(c.metric_calls.iter().sum::<u64>(), c.dtw_calls, "{}", metric.name());
            assert!(c.metric_calls[metric.index()] > 0, "{}", metric.name());
            if !metric.uses_envelopes() {
                // no envelope bound may fire for non-DTW metrics
                assert_eq!(
                    c.lb_kim_prunes
                        + c.lb_keogh_eq_prunes
                        + c.lb_keogh_ec_prunes
                        + c.lb_improved_prunes,
                    0
                );
                assert_eq!(c.dtw_calls, c.candidates, "{}", metric.name());
            }
        }
    }

    #[test]
    fn metric_scan_handles_degenerate_inputs() {
        let r = Dataset::Ecg.generate(64, 3);
        let q: Vec<f64> = (0..128).map(|i| i as f64).collect();
        let mut c = Counters::new();
        // query longer than the reference: empty ranked list, no panic
        let got = search_subsequence_topk_metric(
            &r, &q, 4, 3, Metric::Msm { cost: 0.5 }, Suite::UcrMon, &mut c,
        );
        assert!(got.is_empty());
        // k = 0: empty list
        let got = search_subsequence_topk_metric(
            &r[..32], &q[..8], 2, 0, Metric::Cdtw, Suite::UcrMon, &mut c,
        );
        assert!(got.is_empty());
        // k larger than the candidate count: every window, ranked
        let got = search_subsequence_topk_metric(
            &r, &r[..60], 4, 100, Metric::Cdtw, Suite::UcrMon, &mut c,
        );
        assert_eq!(got.len(), 64 - 60 + 1);
        for pair in got.windows(2) {
            assert!(pair[0].dist <= pair[1].dist);
        }
    }

    #[test]
    fn strip_scan_is_bitwise_identical_to_scalar_scan() {
        let (r, q) = small_workload();
        for suite in Suite::ALL {
            for w_ratio in [0.1, 0.3] {
                let w = window_cells(q.len(), w_ratio);
                for k in [1usize, 5] {
                    let mut cs = Counters::new();
                    let scalar = search_subsequence_topk_metric_mode(
                        &r, &q, w, k, Metric::Cdtw, suite, ScanMode::Scalar, &mut cs,
                    );
                    let mut ct = Counters::new();
                    let strip = search_subsequence_topk_metric_mode(
                        &r, &q, w, k, Metric::Cdtw, suite, ScanMode::Strip, &mut ct,
                    );
                    assert_eq!(scalar.len(), strip.len(), "{} k={k}", suite.name());
                    for (a, b) in scalar.iter().zip(&strip) {
                        assert_eq!(a.pos, b.pos, "{} k={k}", suite.name());
                        assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "{} k={k}", suite.name());
                    }
                    // both looked at every candidate; the strip path did
                    // so in batches
                    assert_eq!(cs.candidates, ct.candidates, "{}", suite.name());
                    assert!(ct.strip_batches > 0, "{}", suite.name());
                    assert_eq!(cs.strip_batches, 0, "{}", suite.name());
                }
            }
        }
    }

    #[test]
    fn strip_scan_with_indexed_stats_matches_streaming_strips() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.2);
        let table = crate::index::ref_index::BucketStats::build(&r, q.len());
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - q.len() + 1;
        let mut run = |stats: ScanStats<'_>| {
            let mut ctx = QueryContext::new(&q, w);
            let mut topk = TopK::new(4);
            let mut c = Counters::new();
            scan_topk_policy_mode(
                &r,
                0,
                total,
                &mut ctx,
                Some(&denv),
                stats,
                Suite::UcrMon,
                Suite::UcrMon.cascade(),
                ScanMode::Strip,
                &mut topk,
                &mut c,
            );
            (topk.into_sorted(), c)
        };
        let (streamed, cs) = run(ScanStats::Streaming);
        let (indexed, ci) = run(ScanStats::Indexed(&table));
        assert_eq!(streamed, indexed);
        assert_eq!(cs.candidates, ci.candidates);
        assert_eq!(cs.strip_batches, ci.strip_batches);
        if ci.lb_keogh_ec_prunes > 0 {
            assert_eq!(ci.index_ec_prunes, ci.lb_keogh_ec_prunes);
        }
        assert_eq!(cs.index_ec_prunes, 0);
    }

    #[test]
    fn strip_scan_cuts_dtw_calls_via_lb_ordering() {
        // the throughput claim in miniature: same results, fewer kernel
        // launches thanks to within-strip LB-ordered threshold tightening
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let mut cs = Counters::new();
        let scalar = search_subsequence_topk_metric_mode(
            &r, &q, w, 5, Metric::Cdtw, Suite::UcrMon, ScanMode::Scalar, &mut cs,
        );
        let mut ct = Counters::new();
        let strip = search_subsequence_topk_metric_mode(
            &r, &q, w, 5, Metric::Cdtw, Suite::UcrMon, ScanMode::Strip, &mut ct,
        );
        assert_eq!(scalar, strip);
        // LB-ordering is a heuristic win, not a theorem: allow a hair of
        // slack so the assertion pins the trend without being brittle
        assert!(
            ct.dtw_calls <= cs.dtw_calls + cs.candidates / 100,
            "strip {} vs scalar {} DTW calls",
            ct.dtw_calls,
            cs.dtw_calls
        );
        assert!(ct.batch_lb_prunes > 0, "{ct:?}");
    }

    #[test]
    fn improved_stage_toggle_preserves_results_bitwise() {
        // the acceptance pin in miniature: LB_Improved on (the default)
        // returns results bit-identical to the pre-improved cascade, in
        // both scan modes, and only ever removes kernel work
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.2);
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - q.len() + 1;
        for mode in [ScanMode::Scalar, ScanMode::Strip] {
            let mut run = |cascade: CascadePolicy| {
                let mut ctx = QueryContext::new(&q, w);
                let mut topk = TopK::new(4);
                let mut c = Counters::new();
                scan_topk_policy_mode(
                    &r,
                    0,
                    total,
                    &mut ctx,
                    Some(&denv),
                    ScanStats::Streaming,
                    Suite::UcrMon,
                    cascade,
                    mode,
                    &mut topk,
                    &mut c,
                );
                (topk.into_sorted(), c)
            };
            let (on, con) = run(CascadePolicy::full());
            let (off, coff) = run(CascadePolicy { improved: false, ..CascadePolicy::full() });
            assert_eq!(on.len(), off.len(), "{mode:?}");
            for (a, b) in on.iter().zip(&off) {
                assert_eq!(a.pos, b.pos, "{mode:?}");
                assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "{mode:?}");
            }
            assert!(con.dtw_calls <= coff.dtw_calls, "{mode:?}: {con:?} vs {coff:?}");
            assert_eq!(coff.lb_improved_prunes, 0, "{mode:?}");
        }
    }

    #[test]
    fn strip_scan_handles_bound_free_metrics_and_short_strips() {
        // a non-envelope metric runs the strip loop bound-free, and a
        // candidate space smaller than one strip still works
        let r = Dataset::Soccer.generate(220, 3);
        let q = crate::data::extract_queries(&r, 1, 64, 0.1, 4).remove(0);
        let metric = Metric::Msm { cost: 0.5 };
        for k in [1usize, 3] {
            let mut cs = Counters::new();
            let scalar = search_subsequence_topk_metric_mode(
                &r, &q, 5, k, metric, Suite::UcrMon, ScanMode::Scalar, &mut cs,
            );
            let mut ct = Counters::new();
            let strip = search_subsequence_topk_metric_mode(
                &r, &q, 5, k, metric, Suite::UcrMon, ScanMode::Strip, &mut ct,
            );
            assert_eq!(scalar.len(), strip.len());
            for (a, b) in scalar.iter().zip(&strip) {
                assert_eq!(a.pos, b.pos);
                assert_eq!(a.dist.to_bits(), b.dist.to_bits());
            }
            // bound-free: every candidate reaches the kernel in both modes
            assert_eq!(ct.dtw_calls, ct.candidates);
            assert_eq!(ct.batch_lb_prunes, 0);
        }
    }

    #[test]
    fn obs_attached_scan_is_bitwise_identical() {
        use crate::obs::{MetricsSnapshot, ObsCell};
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let total = r.len() - q.len() + 1;
        let denv = DataEnvelopes::new(&r, w);
        for mode in [ScanMode::Scalar, ScanMode::Strip] {
            let cell = ObsCell::new();
            let mut run = |obs: ScanObs<'_>| {
                let mut ctx = QueryContext::new(&q, w);
                let mut topk = TopK::new(3);
                let mut c = Counters::new();
                scan_topk_policy_mode_obs(
                    &r,
                    0,
                    total,
                    &mut ctx,
                    Some(&denv),
                    ScanStats::Streaming,
                    Suite::UcrMon,
                    Suite::UcrMon.cascade(),
                    mode,
                    &mut topk,
                    &mut c,
                    obs,
                );
                (topk.into_sorted(), c)
            };
            let (plain, cp) = run(ScanObs::OFF);
            let (observed, co) = run(ScanObs(Some(&cell)));
            assert_eq!(plain.len(), observed.len(), "{mode:?}");
            for (a, b) in plain.iter().zip(&observed) {
                assert_eq!(a.pos, b.pos, "{mode:?}");
                assert_eq!(a.dist.to_bits(), b.dist.to_bits(), "{mode:?}");
            }
            assert_eq!(cp.slots(), co.slots(), "{mode:?}");
            // the attached cell actually saw the stage latencies
            let mut snap = MetricsSnapshot::default();
            cell.drain_into(&mut snap);
            assert!(snap.stages[Stage::BoundKim.index()].count() > 0, "{mode:?}");
            assert!(snap.stages[Stage::KernelEval.index()].count() > 0, "{mode:?}");
            if mode == ScanMode::Strip {
                assert!(snap.dists[DistKind::StripSurvivors.index()].count() > 0);
            }
        }
    }

    #[test]
    fn prepared_cost_cache_never_rebuilds_during_a_scan() {
        let r = Dataset::Soccer.generate(600, 9);
        let q = crate::data::extract_queries(&r, 1, 48, 0.1, 10).remove(0);
        for metric in Metric::all_default() {
            for mode in [ScanMode::Scalar, ScanMode::Strip] {
                let mut c = Counters::new();
                let got = search_subsequence_topk_metric_mode(
                    &r, &q, 5, 2, metric, Suite::UcrMon, mode, &mut c,
                );
                assert!(!got.is_empty(), "{}", metric.name());
                // PR 5 follow-up pinned: the per-query tables are built
                // once at context build, never per candidate
                assert_eq!(c.cost_model_rebuilds, 0, "{} {mode:?}", metric.name());
                assert_eq!(
                    c.dtw_calls,
                    c.dtw_abandons + c.dtw_completions,
                    "{} {mode:?}",
                    metric.name()
                );
            }
        }
    }

    #[test]
    fn try_with_metric_rejects_non_finite_queries() {
        assert!(QueryContext::try_with_metric(&[1.0, f64::NAN, 2.0], 2, Metric::Cdtw).is_err());
        assert!(
            QueryContext::try_with_metric(&[1.0, f64::INFINITY], 1, Metric::Cdtw).is_err()
        );
        let ctx = QueryContext::try_with_metric(&[1.0, 2.0, 3.0], 1, Metric::Cdtw).unwrap();
        assert_eq!(ctx.len(), 3);
        assert!(validate_series("query", &[0.0, 1.0]).is_ok());
        let err = validate_series("query", &[0.0, f64::NEG_INFINITY]).unwrap_err();
        assert!(err.to_string().contains("index 1"), "{err}");
    }

    #[test]
    fn window_cells_matches_paper_grid() {
        assert_eq!(window_cells(1024, 0.1), 102);
        assert_eq!(window_cells(128, 0.5), 64);
        assert_eq!(window_cells(256, 0.2), 51);
    }

    #[test]
    fn finds_planted_exact_copy() {
        // plant the query exactly: distance must be ~0 at that position
        let mut r = Dataset::Ppg.generate(2000, 77);
        let q: Vec<f64> = r[700..828].to_vec();
        // perturb the rest slightly so the plant is unique
        for (i, v) in r.iter_mut().enumerate() {
            if !(700..828).contains(&i) {
                *v += 1e-3 * ((i * 2654435761) % 97) as f64 / 97.0;
            }
        }
        for suite in Suite::ALL {
            let mut c = Counters::new();
            let m = search_subsequence(&r, &q, 12, suite, &mut c);
            assert_eq!(m.pos, 700, "{}", suite.name());
            assert!(m.dist < 1e-9, "{}: {}", suite.name(), m.dist);
        }
    }
}
