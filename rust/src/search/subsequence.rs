//! UCR-style subsequence similarity search (paper §5's workload): slide a
//! z-normalised query over a long reference stream, z-normalising every
//! candidate window on the fly, and collect the top-k matches under an
//! elastic [`Metric`] (windowed DTW by default), pruning with the suite's
//! cascade along the way where the metric's bounds are valid — metrics
//! outside the DTW family ([`Metric::uses_envelopes`] is false) run the
//! bound-free EAPruned scan, still threshold-driven via [`TopK`].
//!
//! The early-abandon threshold is the k-th best distance of a
//! [`TopK`] collector (`k = 1` reproduces the paper's scalar best-so-far
//! bit-for-bit); candidate statistics come either from the seed's
//! streaming recurrence ([`crate::norm::znorm::WindowStats`]) or from a
//! shared precomputed table ([`ScanStats::Indexed`], see
//! [`crate::index::ref_index::RefIndex`]).
//!
//! The loop is allocation-free per candidate: all buffers live in
//! [`QueryContext`] and are reused across the scan.

use crate::bounds::cascade::CascadePolicy;
use crate::bounds::envelope::envelopes_into;
use crate::bounds::lb_keogh::{cumulate_bound, lb_keogh_ec, lb_keogh_eq, reorder, sort_order};
use crate::bounds::lb_kim::lb_kim_hierarchy;
use crate::distances::metric::Metric;
use crate::distances::DtwWorkspace;
use crate::index::ref_index::BucketStats;
use crate::index::topk::TopK;
use crate::metrics::Counters;
use crate::norm::znorm::{znorm, znorm_point, WindowStats};
use crate::search::suite::Suite;

/// A located subsequence match.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Match {
    /// start position in the reference stream
    pub pos: usize,
    /// DTW distance (z-normalised, squared-Euclidean cost)
    pub dist: f64,
}

/// Convert the paper's window *ratio* (0.1–0.5 in the grid) to cells.
pub fn window_cells(qlen: usize, ratio: f64) -> usize {
    (ratio * qlen as f64).floor() as usize
}

/// Everything derived from one (query, window) pair, reusable across scans
/// and shards: the z-normalised query, its sorted order, envelopes, and
/// all work buffers.
#[derive(Debug, Clone)]
pub struct QueryContext {
    /// z-normalised query
    pub q: Vec<f64>,
    /// warping window in cells
    pub w: usize,
    /// indices of `q` by |value| descending
    pub order: Vec<usize>,
    /// q reordered by `order`
    qo: Vec<f64>,
    /// query envelopes reordered by `order`
    uo: Vec<f64>,
    lo: Vec<f64>,
    // work buffers
    cb1: Vec<f64>,
    cb2: Vec<f64>,
    cb_cum: Vec<f64>,
    zbuf: Vec<f64>,
    ws: DtwWorkspace,
    /// elastic metric every candidate is scored under
    pub metric: Metric,
}

impl QueryContext {
    /// Context for the default metric (banded DTW) — every pre-metric
    /// call site, bit-identical to the seed behaviour.
    pub fn new(query_raw: &[f64], w: usize) -> Self {
        Self::with_metric(query_raw, w, Metric::Cdtw)
    }

    /// Context for an arbitrary metric. `w` is re-derived through
    /// [`Metric::effective_window`] (DTW/WDTW are unbanded by
    /// convention), and the envelopes are built for that window.
    pub fn with_metric(query_raw: &[f64], w: usize, metric: Metric) -> Self {
        let q = znorm(query_raw);
        let n = q.len();
        let w = metric.effective_window(n, w);
        // envelopes, sort order and the reordered bounds only exist for
        // metrics whose cascade can use them — a bound-free metric would
        // pay the O(n log n) setup once per shard for nothing
        let (order, qo, uo, lo) = if metric.uses_envelopes() {
            let order = sort_order(&q);
            let mut u = Vec::new();
            let mut l = Vec::new();
            envelopes_into(&q, w, &mut u, &mut l);
            let uo = reorder(&u, &order);
            let lo = reorder(&l, &order);
            let qo = reorder(&q, &order);
            (order, qo, uo, lo)
        } else {
            (Vec::new(), Vec::new(), Vec::new(), Vec::new())
        };
        Self {
            q,
            w,
            order,
            qo,
            uo,
            lo,
            cb1: vec![0.0; n],
            cb2: vec![0.0; n],
            cb_cum: vec![0.0; n + 1],
            zbuf: vec![0.0; n],
            ws: DtwWorkspace::with_capacity(n),
            metric,
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// Envelopes of the *raw* reference stream for one window size — computed
/// once per (reference, w) and shared by every query and shard (LB_Keogh
/// EC z-normalises them per candidate on the fly).
#[derive(Debug, Clone)]
pub struct DataEnvelopes {
    pub upper: Vec<f64>,
    pub lower: Vec<f64>,
}

impl DataEnvelopes {
    pub fn new(reference: &[f64], w: usize) -> Self {
        let mut upper = Vec::new();
        let mut lower = Vec::new();
        envelopes_into(reference, w, &mut upper, &mut lower);
        Self { upper, lower }
    }
}

/// Where a scan gets candidate window statistics from.
#[derive(Debug, Clone, Copy)]
pub enum ScanStats<'a> {
    /// The seed behaviour: one streaming [`WindowStats`] recurrence,
    /// started fresh at the scan's first position.
    Streaming,
    /// A precomputed per-position table shared read-only across queries
    /// and shards ([`crate::index::ref_index::RefIndex::stats_for`]).
    /// Positions index the *full* reference, so every shard sees stats
    /// bit-identical to a full from-zero streaming scan.
    Indexed(&'a BucketStats),
}

/// Scan candidate start positions `[start, end)` of `reference`, beginning
/// from upper bound `bsf` (pass `+inf` for a fresh search). Returns the
/// best match found *below* `bsf` (ties keep the earlier position), or
/// `None` if nothing beat it.
#[allow(clippy::too_many_arguments)]
pub fn scan(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    bsf: f64,
    counters: &mut Counters,
) -> Option<Match> {
    scan_policy(reference, start, end, ctx, denv, suite, suite.cascade(), bsf, counters)
}

/// [`scan`] with an explicit cascade policy (the ablation entry point:
/// any DTW core × any subset of the lower-bound cascade). A thin k = 1
/// wrapper over [`scan_topk_policy`].
#[allow(clippy::too_many_arguments)]
pub fn scan_policy(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    cascade: CascadePolicy,
    bsf: f64,
    counters: &mut Counters,
) -> Option<Match> {
    let mut topk = TopK::with_bound(1, bsf);
    scan_topk_policy(
        reference,
        start,
        end,
        ctx,
        denv,
        ScanStats::Streaming,
        suite,
        cascade,
        &mut topk,
        counters,
    );
    topk.into_sorted().into_iter().next()
}

/// Scan `[start, end)` collecting the top-k matches into `topk` (whose
/// current k-th best / external bound is the early-abandon threshold).
/// This is the shard worker's inner loop; everything scalar-best-so-far
/// in the seed is the `k = 1` case of this function.
#[allow(clippy::too_many_arguments)]
pub fn scan_topk_policy(
    reference: &[f64],
    start: usize,
    end: usize,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    stats: ScanStats<'_>,
    suite: Suite,
    cascade: CascadePolicy,
    topk: &mut TopK,
    counters: &mut Counters,
) {
    let n = ctx.len();
    assert!(n > 0, "empty query");
    assert!(reference.len() >= n, "reference shorter than query");
    let end = end.min(reference.len() - n + 1);
    if start >= end {
        return;
    }
    // metrics outside the DTW family have no valid envelope bounds: the
    // scan degrades to the bound-free EAPruned path, still threshold-driven
    // through the top-k collector
    let cascade = if ctx.metric.uses_envelopes() { cascade } else { CascadePolicy::none() };
    debug_assert!(
        !cascade.needs_data_envelopes() || denv.is_some(),
        "suite {:?} needs data envelopes",
        suite
    );
    match stats {
        ScanStats::Streaming => {
            let mut ws = WindowStats::new(&reference[start..], n);
            loop {
                let pos = start + ws.pos();
                let window = ws.window();
                let (mean, std) = ws.mean_std();
                eval_candidate(
                    pos, window, mean, std, ctx, denv, suite, cascade, false, topk, counters,
                );
                if pos + 1 >= end || !ws.advance() {
                    break;
                }
            }
        }
        ScanStats::Indexed(table) => {
            debug_assert_eq!(table.qlen(), n, "stats bucket / query length mismatch");
            for pos in start..end {
                let window = &reference[pos..pos + n];
                let (mean, std) = table.mean_std(pos);
                eval_candidate(
                    pos, window, mean, std, ctx, denv, suite, cascade, true, topk, counters,
                );
            }
        }
    }
}

/// One candidate through cascade + DTW core + collector. `indexed` marks
/// stats/envelopes as coming from the shared reference index, so its
/// pruning power is attributed separately in the counters.
#[allow(clippy::too_many_arguments)]
fn eval_candidate(
    pos: usize,
    window: &[f64],
    mean: f64,
    std: f64,
    ctx: &mut QueryContext,
    denv: Option<&DataEnvelopes>,
    suite: Suite,
    cascade: CascadePolicy,
    indexed: bool,
    topk: &mut TopK,
    counters: &mut Counters,
) {
    let n = ctx.len();
    counters.candidates += 1;
    // constant for the whole candidate, exactly like the scalar loop's bsf
    let bsf = topk.threshold();
    if cascade.kim {
        let lb = lb_kim_hierarchy(&ctx.q, window, mean, std, bsf);
        if lb > bsf {
            counters.lb_kim_prunes += 1;
            return;
        }
    }
    let mut lb1 = 0.0;
    if cascade.keogh_eq {
        lb1 = lb_keogh_eq(&ctx.order, &ctx.uo, &ctx.lo, window, mean, std, bsf, &mut ctx.cb1);
        if lb1 > bsf {
            counters.lb_keogh_eq_prunes += 1;
            return;
        }
    }
    let mut lb2 = 0.0;
    let mut have2 = false;
    if cascade.keogh_ec {
        let denv = denv.expect("data envelopes required");
        lb2 = lb_keogh_ec(
            &ctx.order,
            &ctx.qo,
            &denv.upper[pos..pos + n],
            &denv.lower[pos..pos + n],
            mean,
            std,
            bsf,
            &mut ctx.cb2,
        );
        have2 = true;
        if lb2 > bsf {
            counters.lb_keogh_ec_prunes += 1;
            if indexed {
                counters.index_ec_prunes += 1;
            }
            return;
        }
    }
    // cumulative tail from the tighter of the two Keogh bounds
    let cb = if cascade.tighten && (cascade.keogh_eq || have2) {
        let src = if have2 && lb2 > lb1 { &ctx.cb2 } else { &ctx.cb1 };
        cumulate_bound(src, &mut ctx.cb_cum);
        Some(ctx.cb_cum.as_slice())
    } else {
        None
    };
    // z-normalise the candidate and run the metric's kernel (the suite's
    // DTW core for the DTW family, the generalised EAPruned elsewhere)
    ctx.zbuf.clear();
    ctx.zbuf.extend(window.iter().map(|&x| znorm_point(x, mean, std)));
    let metric = ctx.metric;
    counters.record_metric_call(metric);
    let d = metric.eval(&ctx.q, &ctx.zbuf, ctx.w, bsf, cb, suite, &mut ctx.ws);
    if d.is_infinite() {
        counters.record_metric_abandon(metric);
    } else if topk.offer(Match { pos, dist: d }) {
        counters.topk_updates += 1;
        counters.ub_updates += 1;
    }
}

/// Full-stream similarity search: the paper's §5 task. Locates the closest
/// z-normalised subsequence of `reference` to `query_raw` under windowed
/// DTW with window `w` (cells).
pub fn search_subsequence(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Match {
    let mut ctx = QueryContext::new(query_raw, w);
    let denv = suite
        .cascade()
        .needs_data_envelopes()
        .then(|| DataEnvelopes::new(reference, w));
    scan(
        reference,
        0,
        reference.len() - ctx.len() + 1,
        &mut ctx,
        denv.as_ref(),
        suite,
        f64::INFINITY,
        counters,
    )
    .expect("fresh search always finds a best match")
}

/// Top-k variant of [`search_subsequence`]: the k closest candidate
/// windows in ascending `(dist, pos)` order (fewer if the reference has
/// fewer than k candidate positions). `k = 1` reproduces
/// [`search_subsequence`] exactly.
pub fn search_subsequence_topk(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    k: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Vec<Match> {
    search_subsequence_topk_metric(reference, query_raw, w, k, Metric::Cdtw, suite, counters)
}

/// Metric-generic top-k subsequence search: the k closest candidate
/// windows of `reference` to the z-normalised query under `metric`,
/// ascending `(dist, pos)`.
///
/// DTW-family metrics keep the full z-norm + envelope cascade fast path;
/// ERP/MSM/TWE/WDTW run the bound-free EAPruned scan, still
/// threshold-driven through the [`TopK`] collector. Degenerate inputs
/// degrade gracefully: a query longer than the reference (zero candidate
/// windows) or `k = 0` returns an empty list, and `k` larger than the
/// candidate count returns every window ranked. Metric parameters are
/// assumed valid ([`Metric::validate`]) — the serving layer validates
/// wire and engine input before reaching this loop.
pub fn search_subsequence_topk_metric(
    reference: &[f64],
    query_raw: &[f64],
    w: usize,
    k: usize,
    metric: Metric,
    suite: Suite,
    counters: &mut Counters,
) -> Vec<Match> {
    let mut ctx = QueryContext::with_metric(query_raw, w, metric);
    if k == 0 || ctx.is_empty() || reference.len() < ctx.len() {
        return Vec::new();
    }
    let denv = metric
        .wants_data_envelopes(suite)
        .then(|| DataEnvelopes::new(reference, ctx.w));
    let mut topk = TopK::new(k);
    scan_topk_policy(
        reference,
        0,
        reference.len() - ctx.len() + 1,
        &mut ctx,
        denv.as_ref(),
        ScanStats::Streaming,
        suite,
        suite.cascade(),
        &mut topk,
        counters,
    );
    topk.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    /// Brute force oracle: exact banded DTW at every position.
    fn brute(reference: &[f64], query_raw: &[f64], w: usize) -> Match {
        let q = znorm(query_raw);
        let n = q.len();
        let mut best = Match { pos: 0, dist: f64::INFINITY };
        let mut ws = DtwWorkspace::default();
        for pos in 0..=(reference.len() - n) {
            let z = znorm(&reference[pos..pos + n]);
            let d = crate::distances::dtw::cdtw_ws(&q, &z, w, &mut ws);
            if d < best.dist {
                best = Match { pos, dist: d };
            }
        }
        best
    }

    fn small_workload() -> (Vec<f64>, Vec<f64>) {
        let r = Dataset::Ecg.generate(3000, 21);
        let q = crate::data::extract_queries(&r, 1, 128, 0.1, 99).remove(0);
        (r, q)
    }

    #[test]
    fn all_suites_agree_with_brute_force() {
        let (r, q) = small_workload();
        for w_ratio in [0.1, 0.3] {
            let w = window_cells(q.len(), w_ratio);
            let want = brute(&r, &q, w);
            for suite in Suite::ALL {
                let mut c = Counters::new();
                let got = search_subsequence(&r, &q, w, suite, &mut c);
                assert_eq!(got.pos, want.pos, "{} w={w}", suite.name());
                assert!(
                    (got.dist - want.dist).abs() < 1e-9,
                    "{} w={w}: {} vs {}",
                    suite.name(),
                    got.dist,
                    want.dist
                );
                assert_eq!(c.candidates, (r.len() - q.len() + 1) as u64);
            }
        }
    }

    #[test]
    fn cascade_actually_prunes() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let mut c = Counters::new();
        search_subsequence(&r, &q, w, Suite::UcrMon, &mut c);
        assert!(
            c.lb_kim_prunes + c.lb_keogh_eq_prunes + c.lb_keogh_ec_prunes > 0,
            "{c:?}"
        );
        assert!(c.dtw_calls < c.candidates);
    }

    #[test]
    fn nolb_reaches_dtw_everywhere() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let mut c = Counters::new();
        search_subsequence(&r, &q, w, Suite::UcrMonNoLb, &mut c);
        assert_eq!(c.dtw_calls, c.candidates, "nolb is 100% DTW (Fig. 5 note)");
        assert!(c.dtw_abandons > 0, "EAP must abandon most candidates");
    }

    #[test]
    fn sharded_scan_equals_full_scan() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.2);
        let suite = Suite::UcrMon;
        let mut c = Counters::new();
        let full = search_subsequence(&r, &q, w, suite, &mut c);
        // two shards sharing the bsf sequentially
        let denv = DataEnvelopes::new(&r, w);
        let mut ctx = QueryContext::new(&q, w);
        let mid = r.len() / 2;
        let mut c1 = Counters::new();
        let m1 = scan(&r, 0, mid, &mut ctx, Some(&denv), suite, f64::INFINITY, &mut c1);
        let bsf = m1.map_or(f64::INFINITY, |m| m.dist);
        let m2 = scan(
            &r,
            mid,
            r.len() - q.len() + 1,
            &mut ctx,
            Some(&denv),
            suite,
            bsf,
            &mut c1,
        );
        let best = match (m1, m2) {
            (Some(a), Some(b)) => {
                if b.dist < a.dist {
                    b
                } else {
                    a
                }
            }
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (None, None) => panic!("no match"),
        };
        assert_eq!(best.pos, full.pos);
        assert!((best.dist - full.dist).abs() < 1e-9);
    }

    #[test]
    fn topk_k1_equals_best_so_far_search() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.2);
        for suite in Suite::ALL {
            let mut c1 = Counters::new();
            let want = search_subsequence(&r, &q, w, suite, &mut c1);
            let mut c2 = Counters::new();
            let got = search_subsequence_topk(&r, &q, w, 1, suite, &mut c2);
            assert_eq!(got, vec![want], "{}", suite.name());
            assert_eq!(c1.dtw_calls, c2.dtw_calls, "{}", suite.name());
        }
    }

    #[test]
    fn topk_is_sorted_prefix_of_brute_force_ranking() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let k = 5;
        let mut c = Counters::new();
        let got = search_subsequence_topk(&r, &q, w, k, Suite::UcrMon, &mut c);
        assert_eq!(got.len(), k);
        // brute-force ranking by (dist, pos)
        let qz = znorm(&q);
        let mut ws = DtwWorkspace::default();
        let mut all: Vec<Match> = (0..=(r.len() - q.len()))
            .map(|pos| {
                let z = znorm(&r[pos..pos + q.len()]);
                Match { pos, dist: crate::distances::dtw::cdtw_ws(&qz, &z, w, &mut ws) }
            })
            .collect();
        all.sort_by(|a, b| {
            a.dist.partial_cmp(&b.dist).unwrap().then(a.pos.cmp(&b.pos))
        });
        for (i, (g, want)) in got.iter().zip(&all).enumerate() {
            assert_eq!(g.pos, want.pos, "rank {i}");
            assert!((g.dist - want.dist).abs() < 1e-9, "rank {i}");
        }
        assert!(c.topk_updates >= k as u64);
    }

    #[test]
    fn indexed_stats_scan_matches_streaming_scan() {
        let (r, q) = small_workload();
        let w = window_cells(q.len(), 0.1);
        let table = crate::index::ref_index::BucketStats::build(&r, q.len());
        let denv = DataEnvelopes::new(&r, w);
        let total = r.len() - q.len() + 1;
        for suite in [Suite::UcrMon, Suite::UcrMonNoLb] {
            let mut ctx = QueryContext::new(&q, w);
            let mut topk = TopK::new(3);
            let mut c = Counters::new();
            scan_topk_policy(
                &r,
                0,
                total,
                &mut ctx,
                Some(&denv),
                ScanStats::Indexed(&table),
                suite,
                suite.cascade(),
                &mut topk,
                &mut c,
            );
            let mut ctx2 = QueryContext::new(&q, w);
            let mut topk2 = TopK::new(3);
            let mut c2 = Counters::new();
            scan_topk_policy(
                &r,
                0,
                total,
                &mut ctx2,
                Some(&denv),
                ScanStats::Streaming,
                suite,
                suite.cascade(),
                &mut topk2,
                &mut c2,
            );
            // the table is built with the same recurrence the streaming
            // scan uses, so the two paths are bit-identical from pos 0
            assert_eq!(topk.into_sorted(), topk2.into_sorted(), "{}", suite.name());
            assert_eq!(c.candidates, c2.candidates);
            if suite.cascade().keogh_ec {
                assert_eq!(c.index_ec_prunes, c.lb_keogh_ec_prunes);
                assert_eq!(c2.index_ec_prunes, 0);
            }
        }
    }

    #[test]
    fn metric_scan_agrees_with_per_window_oracle() {
        let r = Dataset::Soccer.generate(800, 33);
        let q = crate::data::extract_queries(&r, 1, 48, 0.1, 34).remove(0);
        let w = 5;
        for metric in Metric::all_default() {
            let mut c = Counters::new();
            let got = search_subsequence_topk_metric(&r, &q, w, 1, metric, Suite::UcrMon, &mut c);
            assert_eq!(got.len(), 1, "{}", metric.name());
            // brute force with the metric's naive oracle
            let qz = znorm(&q);
            let weff = metric.effective_window(qz.len(), w);
            let mut best = Match { pos: 0, dist: f64::INFINITY };
            for pos in 0..=(r.len() - q.len()) {
                let cz = znorm(&r[pos..pos + q.len()]);
                let d = metric.exact(&qz, &cz, weff);
                if d < best.dist {
                    best = Match { pos, dist: d };
                }
            }
            assert_eq!(got[0].pos, best.pos, "{}", metric.name());
            assert!((got[0].dist - best.dist).abs() < 1e-9, "{}", metric.name());
            // every candidate hit the kernel of the right metric
            assert_eq!(c.metric_calls.iter().sum::<u64>(), c.dtw_calls, "{}", metric.name());
            assert!(c.metric_calls[metric.index()] > 0, "{}", metric.name());
            if !metric.uses_envelopes() {
                // no envelope bound may fire for non-DTW metrics
                assert_eq!(c.lb_kim_prunes + c.lb_keogh_eq_prunes + c.lb_keogh_ec_prunes, 0);
                assert_eq!(c.dtw_calls, c.candidates, "{}", metric.name());
            }
        }
    }

    #[test]
    fn metric_scan_handles_degenerate_inputs() {
        let r = Dataset::Ecg.generate(64, 3);
        let q: Vec<f64> = (0..128).map(|i| i as f64).collect();
        let mut c = Counters::new();
        // query longer than the reference: empty ranked list, no panic
        let got = search_subsequence_topk_metric(
            &r, &q, 4, 3, Metric::Msm { cost: 0.5 }, Suite::UcrMon, &mut c,
        );
        assert!(got.is_empty());
        // k = 0: empty list
        let got = search_subsequence_topk_metric(
            &r[..32], &q[..8], 2, 0, Metric::Cdtw, Suite::UcrMon, &mut c,
        );
        assert!(got.is_empty());
        // k larger than the candidate count: every window, ranked
        let got = search_subsequence_topk_metric(
            &r, &r[..60], 4, 100, Metric::Cdtw, Suite::UcrMon, &mut c,
        );
        assert_eq!(got.len(), 64 - 60 + 1);
        for pair in got.windows(2) {
            assert!(pair[0].dist <= pair[1].dist);
        }
    }

    #[test]
    fn window_cells_matches_paper_grid() {
        assert_eq!(window_cells(1024, 0.1), 102);
        assert_eq!(window_cells(128, 0.5), 64);
        assert_eq!(window_cells(256, 0.2), 51);
    }

    #[test]
    fn finds_planted_exact_copy() {
        // plant the query exactly: distance must be ~0 at that position
        let mut r = Dataset::Ppg.generate(2000, 77);
        let q: Vec<f64> = r[700..828].to_vec();
        // perturb the rest slightly so the plant is unique
        for (i, v) in r.iter_mut().enumerate() {
            if !(700..828).contains(&i) {
                *v += 1e-3 * ((i * 2654435761) % 97) as f64 / 97.0;
            }
        }
        for suite in Suite::ALL {
            let mut c = Counters::new();
            let m = search_subsequence(&r, &q, 12, suite, &mut c);
            assert_eq!(m.pos, 700, "{}", suite.name());
            assert!(m.dist < 1e-9, "{}: {}", suite.name(), m.dist);
        }
    }
}
