//! The suite variants compared in the paper's evaluation (§5). All share
//! the same search loop, cascade code and normalisation — the paper's §2.4
//! point that only same-codebase comparisons are fair — and differ *only*
//! in the DTW core and cascade policy.

use crate::bounds::cascade::CascadePolicy;
use crate::distances::kernel::KernelEval;
use crate::distances::{
    dtw_ea::dtw_ea, eap_dtw::eap_cdtw_eval, pruned_dtw::pruned_cdtw, DtwWorkspace,
};

/// A suite = a DTW core + a cascade policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Suite {
    /// Original UCR suite: full cascade + row-min early-abandoned DTW.
    Ucr,
    /// UCR-USP: full cascade + PrunedDTW.
    UcrUsp,
    /// UCR-MON: full cascade + **EAPrunedDTW** (the paper's system).
    UcrMon,
    /// UCR-MON without lower bounds: EAPrunedDTW does all the work.
    UcrMonNoLb,
    /// Our TPU-shaped variant: batched XLA LB_Keogh prefilter (Layer 1/2)
    /// + EAPrunedDTW on survivors. Driven by the coordinator.
    UcrMonXla,
}

impl Suite {
    pub const ALL: [Suite; 4] = [Suite::Ucr, Suite::UcrUsp, Suite::UcrMon, Suite::UcrMonNoLb];

    pub fn name(&self) -> &'static str {
        match self {
            Suite::Ucr => "UCR",
            Suite::UcrUsp => "UCR-USP",
            Suite::UcrMon => "UCR-MON",
            Suite::UcrMonNoLb => "UCR-MON-nolb",
            Suite::UcrMonXla => "UCR-MON-xla",
        }
    }

    pub fn from_name(s: &str) -> Option<Suite> {
        match s.to_ascii_lowercase().as_str() {
            "ucr" => Some(Suite::Ucr),
            "ucr-usp" | "usp" => Some(Suite::UcrUsp),
            "ucr-mon" | "mon" => Some(Suite::UcrMon),
            "ucr-mon-nolb" | "nolb" => Some(Suite::UcrMonNoLb),
            "ucr-mon-xla" | "xla" => Some(Suite::UcrMonXla),
            _ => None,
        }
    }

    /// Whether this suite's DTW core is the unified EAPruned band kernel
    /// (the UCR-MON family). Only those cores can be widened — the
    /// multi-lane wavefront and the f32 storage mode are kernel features,
    /// so the comparator cores (UCR, UCR-USP) always take the scalar f64
    /// path regardless of tuning.
    #[inline]
    pub fn core_is_eap(&self) -> bool {
        matches!(self, Suite::UcrMon | Suite::UcrMonNoLb | Suite::UcrMonXla)
    }

    pub fn cascade(&self) -> CascadePolicy {
        match self {
            Suite::UcrMonNoLb => CascadePolicy::none(),
            // the XLA prefilter replaces the scalar cascade; the
            // coordinator injects batched bounds instead
            Suite::UcrMonXla => CascadePolicy::none(),
            _ => CascadePolicy::full(),
        }
    }

    /// Evaluate this suite's DTW core: exact distance when `<= ub`, `+inf`
    /// once provably above.
    #[inline]
    pub fn dtw(
        &self,
        q: &[f64],
        c: &[f64],
        w: usize,
        ub: f64,
        cb: Option<&[f64]>,
        ws: &mut DtwWorkspace,
    ) -> f64 {
        self.dtw_eval(q, c, w, ub, cb, ws).dist
    }

    /// [`Suite::dtw`] with the full [`KernelEval`] outcome. The UCR-MON
    /// family runs the unified band kernel, which reports abandons
    /// itself; the UCR / UCR-USP comparator cores predate the outcome
    /// plumbing, so their `+inf` is classified here — an abandon exactly
    /// when the band was feasible (an infeasible band's `+inf` is a
    /// structural answer, not a threshold decision).
    #[inline]
    pub fn dtw_eval(
        &self,
        q: &[f64],
        c: &[f64],
        w: usize,
        ub: f64,
        cb: Option<&[f64]>,
        ws: &mut DtwWorkspace,
    ) -> KernelEval {
        match self {
            Suite::Ucr => {
                let d = dtw_ea(q, c, w, ub, cb, ws);
                let feasible = q.len().abs_diff(c.len()) <= w;
                KernelEval { dist: d, abandoned: d.is_infinite() && feasible }
            }
            Suite::UcrUsp => {
                let d = pruned_cdtw(q, c, w, ub, cb, ws);
                let feasible = q.len().abs_diff(c.len()) <= w;
                KernelEval { dist: d, abandoned: d.is_infinite() && feasible }
            }
            Suite::UcrMon | Suite::UcrMonNoLb | Suite::UcrMonXla => {
                eap_cdtw_eval(q, c, w, ub, cb, ws)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::cdtw;

    #[test]
    fn names_round_trip() {
        for s in Suite::ALL {
            assert_eq!(Suite::from_name(s.name()), Some(s));
        }
        assert_eq!(Suite::from_name("xla"), Some(Suite::UcrMonXla));
    }

    #[test]
    fn all_cores_agree_on_exact_distance() {
        let a = [3.0, 1.0, 4.0, 4.0, 1.0, 1.0];
        let b = [1.0, 3.0, 2.0, 1.0, 2.0, 2.0];
        let mut ws = DtwWorkspace::default();
        for w in [1usize, 3, 6] {
            let want = cdtw(&a, &b, w);
            for s in Suite::ALL {
                let got = s.dtw(&a, &b, w, f64::INFINITY, None, &mut ws);
                assert_eq!(got, want, "{} w={w}", s.name());
                let tie = s.dtw(&a, &b, w, want, None, &mut ws);
                assert_eq!(tie, want, "{} tie w={w}", s.name());
            }
        }
    }

    #[test]
    fn only_the_mon_family_is_lane_eligible() {
        assert!(!Suite::Ucr.core_is_eap());
        assert!(!Suite::UcrUsp.core_is_eap());
        assert!(Suite::UcrMon.core_is_eap());
        assert!(Suite::UcrMonNoLb.core_is_eap());
        assert!(Suite::UcrMonXla.core_is_eap());
    }

    #[test]
    fn cascade_policies() {
        assert!(Suite::Ucr.cascade().any());
        assert!(Suite::UcrUsp.cascade().any());
        assert!(Suite::UcrMon.cascade().any());
        assert!(!Suite::UcrMonNoLb.cascade().any());
        assert!(!Suite::UcrMonXla.cascade().any());
    }
}
