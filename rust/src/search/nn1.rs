//! Whole-series NN1 / k-NN search and classification — the paper's
//! motivating scenario (§1: NN1-DTW is embedded in EE, Proximity Forest,
//! TS-CHIEF; §6: EAPrunedDTW makes those ensembles affordable again).
//!
//! Candidates are visited in ascending LB_Keogh order (best-first), so the
//! upper bound — the k-th best distance of a [`TopK`] collector — tightens
//! as fast as possible and EAPrunedDTW abandons the rest almost
//! immediately. NN1 is the `k = 1` case.

use crate::bounds::envelope::envelopes;
use crate::bounds::lb_improved::{lb_improved_tail_eq, ImprovedScratch};
use crate::bounds::lb_keogh::{reorder, sort_order};
use crate::distances::cache::CostModelCache;
use crate::distances::cost::sqed;
use crate::distances::metric::Metric;
use crate::distances::DtwWorkspace;
use crate::index::topk::TopK;
use crate::metrics::Counters;
use crate::search::subsequence::Match;
use crate::search::suite::Suite;

/// Result of an NN1 search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Nn1Result {
    /// index of the nearest candidate
    pub index: usize,
    /// its windowed DTW distance
    pub dist: f64,
}

/// LB_Keogh of pre-normalised `c` against envelopes of the query
/// (plain order, no candidate stats — whole-series setting).
fn lb_keogh_plain(uo: &[f64], lo: &[f64], order: &[usize], c: &[f64]) -> f64 {
    let mut lb = 0.0;
    for (k, &i) in order.iter().enumerate() {
        let x = c[i];
        if x > uo[k] {
            lb += sqed(x, uo[k]);
        } else if x < lo[k] {
            lb += sqed(x, lo[k]);
        }
    }
    lb
}

/// Find the k nearest neighbours of `query` among `candidates` under
/// windowed DTW (all series assumed pre-normalised and equal length),
/// ascending `(dist, index)`. `suite` picks the DTW core, so the ablation
/// benches can compare cores on k-NN too.
pub fn nn1_topk(
    query: &[f64],
    candidates: &[Vec<f64>],
    w: usize,
    k: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Vec<Nn1Result> {
    nn1_topk_metric(query, candidates, w, k, Metric::Cdtw, suite, counters)
}

/// Metric-generic k-NN: like [`nn1_topk`] but under any elastic
/// [`Metric`]. DTW-family metrics keep the LB_Keogh best-first visit
/// order and pruning; metrics without a valid envelope bound visit the
/// candidates in input order, bound-free, with the k-th best distance
/// still driving EAPruned early abandoning.
pub fn nn1_topk_metric(
    query: &[f64],
    candidates: &[Vec<f64>],
    w: usize,
    k: usize,
    metric: Metric,
    suite: Suite,
    counters: &mut Counters,
) -> Vec<Nn1Result> {
    if candidates.is_empty() || k == 0 {
        return Vec::new();
    }
    let w = metric.effective_window(query.len(), w);
    // natural-order query envelopes outlive the ordering pass: the
    // LB_Improved second pass projects each surviving candidate onto them
    let env = metric.uses_envelopes().then(|| envelopes(query, w));
    let idx: Vec<(usize, f64)> = if let Some((u, l)) = &env {
        let order = sort_order(query);
        let uo = reorder(u, &order);
        let lo = reorder(l, &order);
        // best-first: ascending lower bound
        let mut idx: Vec<(usize, f64)> = candidates
            .iter()
            .enumerate()
            .map(|(i, c)| (i, lb_keogh_plain(&uo, &lo, &order, c)))
            .collect();
        idx.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("no NaN bounds"));
        idx
    } else {
        // no valid lower bound: input order, lb = 0 (never prunes)
        (0..candidates.len()).map(|i| (i, 0.0)).collect()
    };

    let mut ws = DtwWorkspace::with_capacity(query.len());
    // per-query cost-model tables (WDTW weights, ERP accumulators) built
    // once here instead of per candidate; equal-length candidates then
    // never miss the cache
    let mut cache = CostModelCache::new();
    cache.prepare(metric, query);
    let mut topk = TopK::new(k);
    let improved = env.is_some() && suite.cascade().improved;
    let mut iscratch = ImprovedScratch::new();
    for &(i, lb) in &idx {
        counters.candidates += 1;
        let ub = topk.threshold();
        if lb > ub {
            counters.lb_keogh_eq_prunes += 1;
            continue;
        }
        if improved {
            // Lemire's second pass: project the candidate onto the query
            // envelope and charge the projection's own Keogh penalty on
            // top of the first pass (admissible; can prune where plain
            // LB_Keogh is loose)
            let (u, l) = env.as_ref().expect("envelopes built");
            let tail = lb_improved_tail_eq(&mut iscratch, &candidates[i], u, l, query, w, ub - lb);
            if lb + tail > ub {
                counters.lb_improved_prunes += 1;
                continue;
            }
        }
        counters.record_metric_call(metric);
        // exact abandon attribution from the unified kernel: a candidate
        // whose length difference exceeds the band (infeasible, +inf but
        // not abandoned) no longer inflates the abandon tally
        let out =
            metric.eval_outcome_cached(query, &candidates[i], w, ub, None, suite, &mut ws, &mut cache);
        counters.cost_model_rebuilds += cache.take_rebuilds();
        counters.record_metric_outcome(metric, out.abandoned);
        if !out.abandoned && out.dist.is_finite() && topk.offer(Match { pos: i, dist: out.dist }) {
            counters.topk_updates += 1;
            counters.ub_updates += 1;
        }
    }
    topk.into_sorted()
        .into_iter()
        .map(|m| Nn1Result { index: m.pos, dist: m.dist })
        .collect()
}

/// Find the nearest neighbour of `query` among `candidates`: the `k = 1`
/// case of [`nn1_topk`] (bit-identical to the seed's scalar loop).
pub fn nn1_search(
    query: &[f64],
    candidates: &[Vec<f64>],
    w: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Option<Nn1Result> {
    nn1_topk(query, candidates, w, 1, suite, counters).into_iter().next()
}

/// NN1 classification: label of the nearest training series.
pub fn nn1_classify(
    query: &[f64],
    train: &[(usize, Vec<f64>)],
    w: usize,
    suite: Suite,
    counters: &mut Counters,
) -> Option<usize> {
    let series: Vec<Vec<f64>> = train.iter().map(|(_, s)| s.clone()).collect();
    nn1_search(query, &series, w, suite, counters).map(|r| train[r.index].0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distances::dtw::cdtw;
    use crate::norm::znorm::znorm;

    fn xorshift(seed: u64) -> impl FnMut() -> f64 {
        let mut x = seed;
        move || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) * 2.0 - 1.0
        }
    }

    fn mk_candidates(n: usize, len: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rnd = xorshift(seed);
        (0..n)
            .map(|_| znorm(&(0..len).map(|_| rnd()).collect::<Vec<_>>()))
            .collect()
    }

    #[test]
    fn matches_brute_force_for_all_suites() {
        let q = znorm(&mk_candidates(1, 64, 1)[0]);
        let cands = mk_candidates(20, 64, 2);
        for w in [3usize, 16] {
            // brute force
            let mut want = (0usize, f64::INFINITY);
            for (i, c) in cands.iter().enumerate() {
                let d = cdtw(&q, c, w);
                if d < want.1 {
                    want = (i, d);
                }
            }
            for suite in Suite::ALL {
                let mut c = Counters::new();
                let got = nn1_search(&q, &cands, w, suite, &mut c).unwrap();
                assert_eq!(got.index, want.0, "{} w={w}", suite.name());
                assert!((got.dist - want.1).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn prunes_or_abandons_most_candidates() {
        let q = znorm(&mk_candidates(1, 128, 5)[0]);
        let cands = mk_candidates(100, 128, 6);
        let mut c = Counters::new();
        nn1_search(&q, &cands, 12, Suite::UcrMon, &mut c).unwrap();
        assert!(
            c.lb_keogh_eq_prunes + c.dtw_abandons > 50,
            "expected heavy pruning: {c:?}"
        );
    }

    #[test]
    fn improved_stage_conserves_counters_and_results() {
        let q = znorm(&mk_candidates(1, 96, 11)[0]);
        let cands = mk_candidates(60, 96, 12);
        let mut c = Counters::new();
        let got = nn1_topk(&q, &cands, 9, 2, Suite::UcrMon, &mut c);
        // every candidate is accounted to exactly one fate
        assert_eq!(c.candidates, c.lb_keogh_eq_prunes + c.lb_improved_prunes + c.dtw_calls);
        // and the pruned search agrees with the bound-free suite (same
        // DTW core, no lower bounds) on the answer set
        let mut c2 = Counters::new();
        let want = nn1_topk(&q, &cands, 9, 2, Suite::UcrMonNoLb, &mut c2);
        assert_eq!(c2.lb_improved_prunes, 0);
        assert_eq!(got.len(), want.len());
        for (g, x) in got.iter().zip(&want) {
            assert_eq!(g.index, x.index);
            assert_eq!(g.dist.to_bits(), x.dist.to_bits());
        }
    }

    #[test]
    fn classify_picks_nearest_label() {
        // class 0: sine-like; class 1: noise
        let mut rnd = xorshift(9);
        let mk_sine = |phase: f64| {
            znorm(&(0..64).map(|i| (0.2 * i as f64 + phase).sin()).collect::<Vec<_>>())
        };
        let mut train: Vec<(usize, Vec<f64>)> = (0..5).map(|k| (0, mk_sine(k as f64))).collect();
        train.extend((0..5).map(|_| (1usize, znorm(&(0..64).map(|_| rnd()).collect::<Vec<_>>()))));
        let q = mk_sine(0.5);
        let mut c = Counters::new();
        assert_eq!(nn1_classify(&q, &train, 6, Suite::UcrMon, &mut c), Some(0));
    }

    #[test]
    fn empty_candidates() {
        let mut c = Counters::new();
        assert!(nn1_search(&[1.0, 2.0], &[], 1, Suite::UcrMon, &mut c).is_none());
        assert!(nn1_topk(&[1.0, 2.0], &[], 1, 3, Suite::UcrMon, &mut c).is_empty());
    }

    #[test]
    fn metric_topk_matches_brute_force_for_every_metric() {
        let q = znorm(&mk_candidates(1, 48, 7)[0]);
        let cands = mk_candidates(18, 48, 8);
        let w = 5;
        for metric in Metric::all_default() {
            let weff = metric.effective_window(q.len(), w);
            let mut want: Vec<(usize, f64)> = cands
                .iter()
                .enumerate()
                .map(|(i, c)| (i, metric.exact(&q, c, weff)))
                .collect();
            want.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            for k in [1usize, 4] {
                let mut c = Counters::new();
                let got = nn1_topk_metric(&q, &cands, w, k, metric, Suite::UcrMon, &mut c);
                assert_eq!(got.len(), k, "{} k={k}", metric.name());
                for (rank, r) in got.iter().enumerate() {
                    assert_eq!(r.index, want[rank].0, "{} k={k} rank={rank}", metric.name());
                    assert!(
                        (r.dist - want[rank].1).abs() < 1e-9,
                        "{} k={k} rank={rank}",
                        metric.name()
                    );
                }
                assert!(c.metric_calls[metric.index()] > 0, "{}", metric.name());
                // equal-length whole-series search: the prepared tables
                // serve every candidate without a rebuild
                assert_eq!(c.cost_model_rebuilds, 0, "{}", metric.name());
                assert_eq!(
                    c.dtw_calls,
                    c.dtw_abandons + c.dtw_completions,
                    "{}",
                    metric.name()
                );
            }
        }
    }

    #[test]
    fn topk_matches_brute_force_ranking() {
        let q = znorm(&mk_candidates(1, 64, 3)[0]);
        let cands = mk_candidates(30, 64, 4);
        let w = 8;
        let mut want: Vec<(usize, f64)> =
            cands.iter().enumerate().map(|(i, c)| (i, cdtw(&q, c, w))).collect();
        want.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        for k in [1usize, 4, 30] {
            let mut c = Counters::new();
            let got = nn1_topk(&q, &cands, w, k, Suite::UcrMon, &mut c);
            assert_eq!(got.len(), k.min(cands.len()));
            for (rank, r) in got.iter().enumerate() {
                assert_eq!(r.index, want[rank].0, "k={k} rank={rank}");
                assert!((r.dist - want[rank].1).abs() < 1e-9, "k={k} rank={rank}");
            }
        }
    }
}
