//! Benchmark support (system S14): the paper's experiment grid, shared
//! workload construction, a small timing harness (criterion is unavailable
//! offline), and the table/series reporters every bench and the
//! `bench-suite` CLI subcommand print through.

pub mod grid;
pub mod harness;
pub mod report;

use crate::config::GridConfig;
use crate::data::Dataset;

/// Bench scaling knobs via environment (benches can't take CLI args):
/// `REPRO_REF_LEN`, `REPRO_QUERIES`, `REPRO_DATASETS` (comma list),
/// `REPRO_QLENS`, `REPRO_RATIOS`. Defaults keep `cargo bench` minutes-scale
/// on one core; the recorded EXPERIMENTS.md run raises `REPRO_REF_LEN`.
pub fn grid_from_env(default_ref_len: usize) -> (GridConfig, Vec<Dataset>) {
    let env_usize =
        |k: &str, d: usize| std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d);
    let mut grid = GridConfig {
        ref_len: env_usize("REPRO_REF_LEN", default_ref_len),
        queries: env_usize("REPRO_QUERIES", 1),
        ..GridConfig::default()
    };
    if let Ok(v) = std::env::var("REPRO_QLENS") {
        grid.query_lengths = v.split(',').filter_map(|x| x.trim().parse().ok()).collect();
    }
    if let Ok(v) = std::env::var("REPRO_RATIOS") {
        grid.window_ratios = v.split(',').filter_map(|x| x.trim().parse().ok()).collect();
    }
    let datasets = match std::env::var("REPRO_DATASETS") {
        Ok(v) => v.split(',').filter_map(|d| Dataset::from_name(d.trim())).collect(),
        Err(_) => Dataset::ALL.to_vec(),
    };
    (grid, datasets)
}
