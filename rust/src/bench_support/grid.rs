//! The paper's §5 experiment design: 6 datasets × 5 queries × 4 query
//! lengths × 5 window ratios = 600 experiments per suite. Queries of
//! length < 1024 are *prefixes* of the 1024-point queries, exactly as in
//! the paper.

use crate::config::GridConfig;
use crate::data::{extract_queries, Dataset};
use crate::metrics::{Counters, Timer};
use crate::search::subsequence::{search_subsequence, window_cells, Match};
use crate::search::suite::Suite;

/// Base query length the grid extracts (everything else is a prefix).
pub const BASE_QLEN: usize = 1024;

/// One cell of the experiment grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Experiment {
    pub dataset: Dataset,
    pub query_idx: usize,
    pub qlen: usize,
    pub ratio: f64,
}

/// One dataset's materialised workload: the reference stream and the
/// full-length queries (prefix-sliced per experiment).
#[derive(Debug, Clone)]
pub struct Workload {
    pub dataset: Dataset,
    pub reference: Vec<f64>,
    pub queries: Vec<Vec<f64>>,
}

impl Workload {
    pub fn build(dataset: Dataset, grid: &GridConfig) -> Self {
        let reference = dataset.generate(grid.ref_len, grid.seed);
        let queries = extract_queries(
            &reference,
            grid.queries,
            BASE_QLEN.min(grid.ref_len / 2),
            grid.query_noise,
            grid.seed ^ (dataset as u64 + 1),
        );
        Self { dataset, reference, queries }
    }

    /// The prefix query for an experiment.
    pub fn query(&self, exp: &Experiment) -> &[f64] {
        &self.queries[exp.query_idx][..exp.qlen]
    }
}

/// All experiments of the grid, in dataset-major order.
pub fn experiments(grid: &GridConfig, datasets: &[Dataset]) -> Vec<Experiment> {
    let mut out = Vec::new();
    for &dataset in datasets {
        for query_idx in 0..grid.queries {
            for &qlen in &grid.query_lengths {
                for &ratio in &grid.window_ratios {
                    out.push(Experiment { dataset, query_idx, qlen, ratio });
                }
            }
        }
    }
    out
}

/// Result of running one experiment under one suite.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub exp: Experiment,
    pub suite: Suite,
    pub matched: Match,
    pub seconds: f64,
    pub counters: Counters,
}

/// Run one experiment (timed).
pub fn run_experiment(workload: &Workload, exp: &Experiment, suite: Suite) -> RunResult {
    let q = workload.query(exp);
    let w = window_cells(exp.qlen, exp.ratio);
    let mut counters = Counters::new();
    let t = Timer::start();
    let matched = search_subsequence(&workload.reference, q, w, suite, &mut counters);
    RunResult { exp: *exp, suite, matched, seconds: t.elapsed_secs(), counters }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> GridConfig {
        GridConfig {
            ref_len: 4000,
            queries: 2,
            query_lengths: vec![128, 256],
            window_ratios: vec![0.1, 0.3],
            query_noise: 0.1,
            seed: 1,
        }
    }

    #[test]
    fn grid_size_matches_paper_formula() {
        // paper: 5 q × 4 lengths × 5 ratios = 100 per dataset, 600 total
        let g = GridConfig::default();
        let exps = experiments(&g, &Dataset::ALL);
        assert_eq!(exps.len(), 600);
        let one = experiments(&g, &[Dataset::Ecg]);
        assert_eq!(one.len(), 100);
    }

    #[test]
    fn queries_are_prefixes() {
        let g = tiny_grid();
        let w = Workload::build(Dataset::Ppg, &g);
        let e128 = Experiment { dataset: Dataset::Ppg, query_idx: 0, qlen: 128, ratio: 0.1 };
        let e256 = Experiment { dataset: Dataset::Ppg, query_idx: 0, qlen: 256, ratio: 0.1 };
        assert_eq!(w.query(&e128), &w.query(&e256)[..128]);
    }

    #[test]
    fn experiments_run_and_agree_across_suites() {
        let g = tiny_grid();
        let w = Workload::build(Dataset::Ecg, &g);
        let exp = Experiment { dataset: Dataset::Ecg, query_idx: 0, qlen: 128, ratio: 0.1 };
        let results: Vec<RunResult> =
            Suite::ALL.iter().map(|&s| run_experiment(&w, &exp, s)).collect();
        for r in &results[1..] {
            assert_eq!(r.matched.pos, results[0].matched.pos, "{}", r.suite.name());
            assert!((r.matched.dist - results[0].matched.dist).abs() < 1e-9);
        }
    }
}
