//! Tiny timing harness (criterion is unavailable offline): warmup +
//! repeated measurement with min/median/mean reporting. Used by every
//! target under `rust/benches/`.

use crate::metrics::Timer;

/// Timing summary over repetitions, in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub reps: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).expect("no NaN timings"));
        let reps = samples.len();
        Stats {
            reps,
            min: samples[0],
            median: samples[reps / 2],
            mean: samples.iter().sum::<f64>() / reps as f64,
            max: samples[reps - 1],
        }
    }
}

/// Measure `f` with `warmup` unrecorded runs then `reps` timed runs.
/// The closure's return value is passed through `std::hint::black_box` so
/// the work cannot be optimised away.
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let samples = (0..reps.max(1))
        .map(|_| {
            let t = Timer::start();
            std::hint::black_box(f());
            t.elapsed_secs()
        })
        .collect();
    Stats::from_samples(samples)
}

/// Pretty duration (µs/ms/s auto-scale).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_reps() {
        let mut count = 0;
        let s = bench(2, 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 7);
        assert_eq!(s.reps, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
