//! Table/series reporters: fixed-width text tables matching the rows and
//! series the paper's Figure 5 and §5 text report, so `cargo bench` output
//! reads side-by-side with the paper.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::bench_support::grid::RunResult;
use crate::data::Dataset;
use crate::metrics::Counters;
use crate::obs::MetricsSnapshot;
use crate::search::suite::Suite;
use crate::util::json::{obj, Json};

/// Fixed-width table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Machine-readable bench results: every bench target collects its runs
/// into one of these and writes `BENCH_<name>.json` next to where it ran
/// (override the directory with `REPRO_BENCH_DIR`), so the perf
/// trajectory is tracked across PRs instead of scrolling away with the
/// terminal. One JSON object per file: suite name, unix timestamp, and a
/// `runs` array whose rows carry whatever fields the bench pushes —
/// [`BenchJson::push_result`] standardises the grid-shaped ones
/// (suite, dataset, ns/op, DP cells, prune counters).
#[derive(Debug, Clone)]
pub struct BenchJson {
    name: String,
    runs: Vec<Json>,
    stats: Option<Json>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), runs: Vec::new(), stats: None }
    }

    /// Embed a pipeline metrics snapshot (pinned schema
    /// `repro.metrics.v1`) under a top-level `stats` key, so each
    /// `BENCH_*.json` carries the full observability document for the
    /// run — `tools/bench_diff.py` checks the counter-conservation
    /// identities on it when comparing two artifacts.
    pub fn set_stats(&mut self, snapshot: &MetricsSnapshot) {
        self.stats = Some(snapshot.to_json());
    }

    /// Push one run row with arbitrary fields.
    pub fn push(&mut self, fields: Vec<(&str, Json)>) {
        self.runs.push(obj(fields));
    }

    /// Push one grid experiment result in the standard shape.
    pub fn push_result(&mut self, r: &RunResult) {
        self.push(vec![
            ("suite", Json::Str(r.suite.name().to_string())),
            ("dataset", Json::Str(r.exp.dataset.name().to_string())),
            ("qlen", Json::Num(r.exp.qlen as f64)),
            ("ratio", Json::Num(r.exp.ratio)),
            ("seconds", Json::Num(r.seconds)),
            ("ns_per_op", Json::Num(r.seconds * 1e9)),
            ("counters", Self::counters_json(&r.counters)),
        ]);
    }

    /// The counters fields every consumer of the JSON can rely on.
    pub fn counters_json(c: &Counters) -> Json {
        obj(vec![
            ("candidates", Json::Num(c.candidates as f64)),
            ("lb_kim_prunes", Json::Num(c.lb_kim_prunes as f64)),
            ("lb_keogh_eq_prunes", Json::Num(c.lb_keogh_eq_prunes as f64)),
            ("lb_keogh_ec_prunes", Json::Num(c.lb_keogh_ec_prunes as f64)),
            ("lb_improved_prunes", Json::Num(c.lb_improved_prunes as f64)),
            ("xla_prunes", Json::Num(c.xla_prunes as f64)),
            ("dtw_calls", Json::Num(c.dtw_calls as f64)),
            ("dtw_abandons", Json::Num(c.dtw_abandons as f64)),
            ("dtw_completions", Json::Num(c.dtw_completions as f64)),
            ("cost_model_rebuilds", Json::Num(c.cost_model_rebuilds as f64)),
            ("dp_cells", Json::Num(c.dp_cells as f64)),
            ("strip_batches", Json::Num(c.strip_batches as f64)),
            ("batch_lb_prunes", Json::Num(c.batch_lb_prunes as f64)),
            (
                "lb_order_saved_dtw_calls",
                Json::Num(c.lb_order_saved_dtw_calls as f64),
            ),
            ("cohort_strips", Json::Num(c.cohort_strips as f64)),
            (
                "cohort_retired_queries",
                Json::Num(c.cohort_retired_queries as f64),
            ),
            (
                "strip_stat_loads_saved",
                Json::Num(c.strip_stat_loads_saved as f64),
            ),
            ("kernel_multi_calls", Json::Num(c.kernel_multi_calls as f64)),
            (
                "kernel_lanes_filled",
                Json::Num(c.kernel_lanes_filled as f64),
            ),
            (
                "kernel_lane_abandons",
                Json::Num(c.kernel_lane_abandons as f64),
            ),
        ])
    }

    /// The full document (testable without touching the filesystem).
    pub fn to_json(&self) -> Json {
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut fields = vec![
            ("bench", Json::Str(self.name.clone())),
            ("created_unix", Json::Num(created as f64)),
            ("runs", Json::Arr(self.runs.clone())),
        ];
        if let Some(stats) = &self.stats {
            fields.push(("stats", stats.clone()));
        }
        obj(fields)
    }

    /// Write `BENCH_<name>.json` into `REPRO_BENCH_DIR` (default: the
    /// current directory) and return the path.
    pub fn write(&self) -> Result<PathBuf> {
        let dir = std::env::var("REPRO_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_to(Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string() + "\n")?;
        Ok(path)
    }

    /// Write and report where, tolerating a read-only filesystem (benches
    /// must keep printing their tables even if the artifact can't land).
    pub fn write_and_announce(&self) {
        match self.write() {
            Ok(p) => eprintln!("bench json: {}", p.display()),
            Err(e) => eprintln!("bench json NOT written: {e:#}"),
        }
    }
}

/// Average runtime per (dataset, suite, key) where `key` extracts the
/// x-axis (query length for Fig 5a, window ratio ×100 for Fig 5b).
pub fn average_series(
    results: &[RunResult],
    key: impl Fn(&RunResult) -> usize,
) -> BTreeMap<(Dataset, Suite, usize), f64> {
    let mut acc: BTreeMap<(Dataset, Suite, usize), (f64, usize)> = BTreeMap::new();
    for r in results {
        let e = acc.entry((r.exp.dataset, r.suite, key(r))).or_insert((0.0, 0));
        e.0 += r.seconds;
        e.1 += 1;
    }
    acc.into_iter().map(|(k, (s, n))| (k, s / n as f64)).collect()
}

/// Render a Fig-5-style table: one block per dataset, rows = suites,
/// columns = x-axis values.
pub fn fig5_table(
    results: &[RunResult],
    suites: &[Suite],
    xs: &[usize],
    x_label: &str,
    key: impl Fn(&RunResult) -> usize,
) -> String {
    let series = average_series(results, key);
    let datasets: Vec<Dataset> = Dataset::ALL
        .into_iter()
        .filter(|d| results.iter().any(|r| r.exp.dataset == *d))
        .collect();
    let mut out = String::new();
    for d in datasets {
        out.push_str(&format!("\n== {} — avg runtime by {x_label} ==\n", d.name()));
        let mut header = vec!["suite".to_string()];
        header.extend(xs.iter().map(|x| x.to_string()));
        let mut t = Table::new(header);
        for &s in suites {
            let mut row = vec![s.name().to_string()];
            for &x in xs {
                match series.get(&(d, s, x)) {
                    Some(v) => row.push(format!("{:.3}s", v)),
                    None => row.push("-".to_string()),
                }
            }
            t.row(row);
        }
        out.push_str(&t.render());
    }
    out
}

/// The §5 headline numbers: total seconds per suite + speedups vs UCR and
/// UCR-USP, plus slower-case statistics (paper T1/T2).
pub fn speedup_summary(results: &[RunResult]) -> String {
    let mut totals: BTreeMap<Suite, f64> = BTreeMap::new();
    for r in results {
        *totals.entry(r.suite).or_insert(0.0) += r.seconds;
    }
    let ucr = totals.get(&Suite::Ucr).copied();
    let usp = totals.get(&Suite::UcrUsp).copied();
    let mut t = Table::new(vec!["suite", "total", "vs UCR", "vs UCR-USP"]);
    for (s, secs) in &totals {
        t.row(vec![
            s.name().to_string(),
            format!("{secs:.3}s"),
            ucr.map_or("-".into(), |u| format!("{:.3}x", u / secs)),
            usp.map_or("-".into(), |u| format!("{:.3}x", u / secs)),
        ]);
    }
    let mut out = t.render();
    // per-run slower-than statistics (paper T2)
    let mut by_key: BTreeMap<(Dataset, usize, usize, usize), BTreeMap<Suite, f64>> =
        BTreeMap::new();
    for r in results {
        by_key
            .entry((
                r.exp.dataset,
                r.exp.query_idx,
                r.exp.qlen,
                (r.exp.ratio * 100.0).round() as usize,
            ))
            .or_default()
            .insert(r.suite, r.seconds);
    }
    for (a, b) in [(Suite::UcrMon, Suite::Ucr), (Suite::UcrMon, Suite::UcrUsp), (Suite::UcrUsp, Suite::Ucr)]
    {
        let mut slower = 0usize;
        let mut total = 0usize;
        let mut sum_delta = 0.0;
        let mut max_delta: f64 = 0.0;
        for times in by_key.values() {
            if let (Some(&ta), Some(&tb)) = (times.get(&a), times.get(&b)) {
                total += 1;
                if ta > tb {
                    slower += 1;
                    sum_delta += ta - tb;
                    max_delta = max_delta.max(ta - tb);
                }
            }
        }
        if total > 0 {
            out.push_str(&format!(
                "{} slower than {} in {}/{} runs ({:.1}%), avg +{:.4}s, max +{:.4}s\n",
                a.name(),
                b.name(),
                slower,
                total,
                100.0 * slower as f64 / total as f64,
                if slower > 0 { sum_delta / slower as f64 } else { 0.0 },
                max_delta,
            ));
        }
    }
    out
}

/// The Fig-5 inset: per-dataset cascade pruning proportions.
pub fn pruning_table(results: &[RunResult]) -> String {
    let mut t = Table::new(vec![
        "dataset", "suite", "kim%", "keoghEQ%", "keoghEC%", "keoghIMP%", "dtw%", "abandon%",
    ]);
    let mut acc: BTreeMap<(Dataset, Suite), crate::metrics::Counters> = BTreeMap::new();
    for r in results {
        acc.entry((r.exp.dataset, r.suite))
            .or_default()
            .merge(&r.counters);
    }
    for ((d, s), c) in &acc {
        let (kim, eq, ec, imp, _xla, dtw) = c.prune_fractions();
        let ab = if c.dtw_calls > 0 {
            c.dtw_abandons as f64 / c.dtw_calls as f64
        } else {
            0.0
        };
        t.row(vec![
            d.name().to_string(),
            s.name().to_string(),
            format!("{:.1}", kim * 100.0),
            format!("{:.1}", eq * 100.0),
            format!("{:.1}", ec * 100.0),
            format!("{:.1}", imp * 100.0),
            format!("{:.1}", dtw * 100.0),
            format!("{:.1}", ab * 100.0),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::grid::{run_experiment, Experiment, Workload};
    use crate::config::GridConfig;

    fn small_results() -> Vec<RunResult> {
        let g = GridConfig {
            ref_len: 3000,
            queries: 1,
            query_lengths: vec![128],
            window_ratios: vec![0.1, 0.2],
            query_noise: 0.1,
            seed: 3,
        };
        let w = Workload::build(Dataset::Ecg, &g);
        let mut out = Vec::new();
        for ratio in [0.1, 0.2] {
            let exp = Experiment { dataset: Dataset::Ecg, query_idx: 0, qlen: 128, ratio };
            for s in [Suite::Ucr, Suite::UcrUsp, Suite::UcrMon] {
                out.push(run_experiment(&w, &exp, s));
            }
        }
        out
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["333", "4"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(vec!["a"]);
        t.row(vec!["1", "2"]);
    }

    #[test]
    fn bench_json_document_has_standard_fields() {
        let results = small_results();
        let mut bj = BenchJson::new("unit_test");
        for r in &results {
            bj.push_result(r);
        }
        bj.push(vec![("custom", Json::Num(1.0))]);
        let doc = bj.to_json();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("unit_test"));
        assert!(doc.get("created_unix").and_then(Json::as_f64).unwrap() > 0.0);
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), results.len() + 1);
        let first = &runs[0];
        assert_eq!(first.get("dataset").and_then(Json::as_str), Some("ECG"));
        assert!(first.get("ns_per_op").and_then(Json::as_f64).unwrap() > 0.0);
        let counters = first.get("counters").unwrap();
        for key in [
            "candidates",
            "dtw_calls",
            "dtw_completions",
            "cost_model_rebuilds",
            "xla_prunes",
            "strip_batches",
            "lb_order_saved_dtw_calls",
        ] {
            assert!(counters.get(key).is_some(), "missing {key}");
        }
        // no stats were attached: the key stays absent entirely
        assert!(doc.get("stats").is_none());
        // the document is valid JSON end to end
        assert!(Json::parse(&doc.to_string()).is_ok());
    }

    #[test]
    fn bench_json_embeds_a_pinned_schema_snapshot() {
        let results = small_results();
        let mut total = Counters::new();
        for r in &results {
            total.merge(&r.counters);
        }
        let mut bj = BenchJson::new("stats_test");
        bj.push_result(&results[0]);
        bj.set_stats(&MetricsSnapshot::from_counters(&total));
        let doc = bj.to_json();
        let stats = doc.get("stats").expect("stats embedded");
        assert_eq!(
            stats.get("schema").and_then(Json::as_str),
            Some(crate::obs::SCHEMA)
        );
        // the embedded document round-trips through the snapshot parser
        let back = MetricsSnapshot::from_json(stats).unwrap();
        assert_eq!(back.counters.candidates, total.candidates);
        assert_eq!(back.counters.dtw_calls, total.dtw_calls);
    }

    #[test]
    fn bench_json_writes_to_the_chosen_dir() {
        // write_to takes the directory explicitly — mutating the
        // process-global env in a parallel test harness would race
        let dir = std::env::temp_dir().join(format!("repro_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut bj = BenchJson::new("write_test");
        bj.push(vec![("seconds", Json::Num(0.25))]);
        let path = bj.write_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap(), "BENCH_write_test.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("write_test"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reports_render() {
        let results = small_results();
        let fig = fig5_table(
            &results,
            &[Suite::Ucr, Suite::UcrUsp, Suite::UcrMon],
            &[10, 20],
            "window%",
            |r| (r.exp.ratio * 100.0).round() as usize,
        );
        assert!(fig.contains("ECG"));
        assert!(fig.contains("UCR-MON"));
        let sp = speedup_summary(&results);
        assert!(sp.contains("vs UCR"));
        let pt = pruning_table(&results);
        assert!(pt.contains("dtw%"));
    }
}
