//! Fault injection (failpoints-style) for the coordinator's failure
//! model. The whole module is behind the `fault-inject` cargo feature:
//! the default build compiles the inert inline stubs below, so no fault
//! site adds a single instruction to production code paths.
//!
//! A *site* is a named point in the pipeline that consults the registry
//! when it is reached. Armed sites fire a bounded number of times and
//! then disarm, so a respawned worker doesn't re-trip the same fault.
//! Sites compiled in today:
//!
//! | site | location | effect when fired |
//! |------|----------|-------------------|
//! | `worker.panic` | worker loop, on job receipt | `panic!` inside the worker's catch_unwind domain |
//! | `worker.exit` | worker loop, on job receipt | the worker thread returns (genuine death: its channel closes) |
//! | `reply.drop` | worker loop, on job receipt | the job is dropped without a reply (fan-in sees a closed channel) |
//! | `strip.stall` | `scan_topk_strips`, at each strip boundary | sleeps for the armed duration (a slow scan for deadline tests) |
//! | `conn.stall` | net reader, before dispatching a parsed frame | sleeps for the armed duration (a slow connection for drain tests) |
//! | `conn.drop` | net reader, before dispatching a parsed frame | closes the connection as if the client vanished mid-session |
//! | `accept.fail` | net accept loop, on a new connection | the accepted socket is dropped without a reply (a transient accept error) |
//!
//! Tests arm sites in-process via [`arm`] / [`arm_stall`]; standalone
//! binaries can arm at startup through the `REPRO_FAULTS` environment
//! variable (`site=count` or `site=count:stall_ms`, comma-separated),
//! read once on first use. The registry is a global mutex — tests that
//! arm faults must serialise themselves (the conformance suite holds its
//! own lock) because cargo runs tests concurrently.

#[cfg(feature = "fault-inject")]
mod enabled {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::time::Duration;

    #[derive(Debug, Clone, Copy)]
    struct Armed {
        /// remaining times this site fires before disarming
        remaining: u64,
        /// stall duration for sleep sites (zero for trip sites)
        stall: Duration,
    }

    fn registry() -> &'static Mutex<HashMap<String, Armed>> {
        static REGISTRY: OnceLock<Mutex<HashMap<String, Armed>>> = OnceLock::new();
        REGISTRY.get_or_init(|| {
            let mut map = HashMap::new();
            if let Ok(spec) = std::env::var("REPRO_FAULTS") {
                for part in spec.split(',').filter(|p| !p.is_empty()) {
                    if let Some((site, rest)) = part.split_once('=') {
                        let (count, stall_ms) = match rest.split_once(':') {
                            Some((c, s)) => (c.parse().unwrap_or(0), s.parse().unwrap_or(0)),
                            None => (rest.parse().unwrap_or(0), 0u64),
                        };
                        map.insert(
                            site.to_string(),
                            Armed {
                                remaining: count,
                                stall: Duration::from_millis(stall_ms),
                            },
                        );
                    }
                }
            }
            Mutex::new(map)
        })
    }

    /// Arm `site` to fire `count` times, then disarm.
    pub fn arm(site: &str, count: u64) {
        registry()
            .lock()
            .unwrap()
            .insert(site.to_string(), Armed { remaining: count, stall: Duration::ZERO });
    }

    /// Arm a stall site: each of the next `count` passages sleeps
    /// `stall_ms` milliseconds.
    pub fn arm_stall(site: &str, stall_ms: u64, count: u64) {
        registry().lock().unwrap().insert(
            site.to_string(),
            Armed { remaining: count, stall: Duration::from_millis(stall_ms) },
        );
    }

    /// Disarm every site.
    pub fn reset() {
        registry().lock().unwrap().clear();
    }

    /// Consult a trip site: true exactly `count` times after [`arm`].
    pub fn fire(site: &str) -> bool {
        let mut map = registry().lock().unwrap();
        match map.get_mut(site) {
            Some(armed) if armed.remaining > 0 => {
                armed.remaining -= 1;
                true
            }
            _ => false,
        }
    }

    /// Consult a stall site: sleeps the armed duration if armed, and
    /// reports whether it stalled.
    pub fn fire_stall(site: &str) -> bool {
        let stall = {
            let mut map = registry().lock().unwrap();
            match map.get_mut(site) {
                Some(armed) if armed.remaining > 0 => {
                    armed.remaining -= 1;
                    Some(armed.stall)
                }
                _ => None,
            }
        };
        // sleep outside the lock so a long stall can't serialise other sites
        match stall {
            Some(d) => {
                std::thread::sleep(d);
                true
            }
            None => false,
        }
    }
}

#[cfg(feature = "fault-inject")]
pub use enabled::{arm, arm_stall, fire, fire_stall, reset};

// Default build: inert stubs. `#[inline(always)]` + constant returns let
// every `if fault::fire(..)` site fold away entirely.
#[cfg(not(feature = "fault-inject"))]
mod disabled {
    #[inline(always)]
    pub fn arm(_site: &str, _count: u64) {}
    #[inline(always)]
    pub fn arm_stall(_site: &str, _stall_ms: u64, _count: u64) {}
    #[inline(always)]
    pub fn reset() {}
    #[inline(always)]
    pub fn fire(_site: &str) -> bool {
        false
    }
    #[inline(always)]
    pub fn fire_stall(_site: &str) -> bool {
        false
    }
}

#[cfg(not(feature = "fault-inject"))]
pub use disabled::{arm, arm_stall, fire, fire_stall, reset};

/// Site name: panic inside the worker loop on job receipt.
pub const WORKER_PANIC: &str = "worker.panic";
/// Site name: the worker thread returns (genuine death).
pub const WORKER_EXIT: &str = "worker.exit";
/// Site name: the job is dropped without a reply.
pub const REPLY_DROP: &str = "reply.drop";
/// Site name: sleep at each strip boundary of `scan_topk_strips`.
pub const STRIP_STALL: &str = "strip.stall";
/// Site name: sleep in the net reader before dispatching a parsed frame.
pub const CONN_STALL: &str = "conn.stall";
/// Site name: the net reader closes the connection as if the client
/// vanished mid-session.
pub const CONN_DROP: &str = "conn.drop";
/// Site name: the accept loop drops a freshly accepted socket.
pub const ACCEPT_FAIL: &str = "accept.fail";

#[cfg(all(test, feature = "fault-inject"))]
mod tests {
    use super::*;

    // NOTE: the registry is process-global; this module keeps all its
    // assertions in one #[test] so cargo's parallel runner can't
    // interleave arms and fires.
    #[test]
    fn sites_fire_armed_count_then_disarm() {
        reset();
        assert!(!fire(WORKER_PANIC), "unarmed site must not fire");
        arm(WORKER_PANIC, 2);
        assert!(fire(WORKER_PANIC));
        assert!(fire(WORKER_PANIC));
        assert!(!fire(WORKER_PANIC), "site must disarm after its count");
        arm_stall(STRIP_STALL, 1, 1);
        assert!(fire_stall(STRIP_STALL));
        assert!(!fire_stall(STRIP_STALL));
        arm(REPLY_DROP, 1);
        reset();
        assert!(!fire(REPLY_DROP), "reset must disarm everything");
    }
}

#[cfg(all(test, not(feature = "fault-inject")))]
mod tests {
    use super::*;

    #[test]
    fn disabled_stubs_are_inert() {
        arm(WORKER_PANIC, 10);
        arm_stall(STRIP_STALL, 5, 10);
        assert!(!fire(WORKER_PANIC));
        assert!(!fire_stall(STRIP_STALL));
        reset();
    }
}
