//! The batched multi-query search engine: one shared [`RefIndex`], one
//! shard-worker pool, many concurrent top-k queries.
//!
//! [`Engine::search_batch`] is the amortisation point the index exists
//! for: the first query of a batch pays to build the stats bucket and
//! envelope arrays; every later query (and every later batch) reuses them
//! for free. `benches/index_amortization.rs` measures the per-query cost
//! falling as the batch grows.
//!
//! Batches amortise the *streaming* too: in the default
//! [`BatchMode::Cohort`], same-shape queries share one strip-major pass
//! over the reference (`search::cohort`), so a batch of Q queries streams
//! the reference's stat lanes once instead of Q times —
//! `benches/cohort_throughput.rs` measures reference bytes per query
//! falling as the batch grows, with results pinned bitwise-identical to
//! sequential serving by `tests/conformance_cohort.rs`.

use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::coordinator::router::{route_cohort_topk, route_query_topk};
use crate::coordinator::worker::{worker_loop, WorkItem, DEFAULT_SYNC_EVERY};
use crate::distances::metric::Metric;
use crate::index::ref_index::RefIndex;
use crate::metrics::Counters;
use crate::search::subsequence::{validate_series, window_cells, Match, ScanMode, ScanTuning};
use crate::search::suite::Suite;

/// One query of a batch: raw (un-normalised) points plus its warping
/// window as a ratio of the query length, the paper's §5 convention, and
/// the elastic metric it is scored under (cDTW by default).
#[derive(Debug, Clone, PartialEq)]
pub struct Query {
    pub query: Vec<f64>,
    pub window_ratio: f64,
    pub metric: Metric,
}

impl Query {
    pub fn new(query: Vec<f64>, window_ratio: f64) -> Self {
        Self::with_metric(query, window_ratio, Metric::Cdtw)
    }

    pub fn with_metric(query: Vec<f64>, window_ratio: f64, metric: Metric) -> Self {
        Self { query, window_ratio, metric }
    }
}

/// The k best matches of one query, ascending `(dist, pos)`, plus the
/// aggregated counters of its sharded scan.
#[derive(Debug, Clone)]
pub struct TopKResult {
    pub matches: Vec<Match>,
    pub counters: Counters,
}

impl TopKResult {
    /// The single best match. Panics if `matches` is empty — which only
    /// happens when the query had zero candidate windows (longer than the
    /// reference); any scan over at least one window accepts a match.
    pub fn best(&self) -> Match {
        self.matches[0]
    }
}

/// How [`Engine::search_batch`] walks a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// Query-major: each query is an independent [`Engine::search_one`]
    /// fan-out, streaming the reference once per query. The A/B baseline.
    Sequential,
    /// Strip-major (the default): same-shape queries form *cohorts* and
    /// each cohort runs one shared strip pass over the reference — each
    /// strip's window-stat lanes are loaded once for the whole cohort.
    /// Results are bitwise-identical to `Sequential`
    /// (`tests/conformance_cohort.rs`). Requires [`ScanMode::Strip`]
    /// workers; a scalar-mode engine falls back to `Sequential`.
    #[default]
    Cohort,
}

/// Engine construction knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// shard workers the candidate space is split across
    pub shards: usize,
    /// positions between shared-threshold syncs in the workers
    pub sync_every: usize,
    /// DTW core + cascade policy every query runs under
    pub suite: Suite,
    /// scan front-end the shard workers run (strip-mined by default; the
    /// legacy scalar loop stays callable for A/B — both return bitwise
    /// identical matches)
    pub scan_mode: ScanMode,
    /// batch front-end: cohort (strip-major, shared reference streaming)
    /// by default, sequential as the A/B baseline — both return bitwise
    /// identical results
    pub batch: BatchMode,
    /// kernel tuning the shard workers scan with: wavefront lane width
    /// (1 = scalar kernel, the default) and DP line precision
    pub tuning: ScanTuning,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            sync_every: DEFAULT_SYNC_EVERY,
            suite: Suite::UcrMon,
            scan_mode: ScanMode::default(),
            batch: BatchMode::default(),
            tuning: ScanTuning::default(),
        }
    }
}

/// A running multi-query engine over one indexed reference stream.
pub struct Engine {
    index: Arc<RefIndex>,
    suite: Suite,
    sync_every: usize,
    scan_mode: ScanMode,
    batch: BatchMode,
    tuning: ScanTuning,
    senders: Vec<Sender<WorkItem>>,
    handles: Vec<JoinHandle<()>>,
    busy: Arc<AtomicU64>,
}

impl Engine {
    /// Index `reference` and spawn the worker pool.
    pub fn new(reference: Vec<f64>, cfg: &EngineConfig) -> Result<Self> {
        Self::over_index(Arc::new(RefIndex::new(Arc::new(reference))), cfg)
    }

    /// Spawn a pool over an existing (possibly already warm) index —
    /// several engines can share one index of the same stream.
    pub fn over_index(index: Arc<RefIndex>, cfg: &EngineConfig) -> Result<Self> {
        anyhow::ensure!(cfg.shards >= 1, "need at least one shard");
        anyhow::ensure!(index.reference_len() > 0, "empty reference");
        // a NaN/inf point in the reference would poison every scan's
        // bounds and heaps; reject it once, before any worker spawns
        validate_series("reference", index.reference())?;
        let busy = Arc::new(AtomicU64::new(0));
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for i in 0..cfg.shards {
            let (tx, rx) = channel::<WorkItem>();
            let busy = Arc::clone(&busy);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("engine-shard-{i}"))
                    .spawn(move || worker_loop(rx, busy, None))?,
            );
            senders.push(tx);
        }
        Ok(Self {
            index,
            suite: cfg.suite,
            sync_every: cfg.sync_every,
            scan_mode: cfg.scan_mode,
            batch: cfg.batch,
            tuning: cfg.tuning,
            senders,
            handles,
            busy,
        })
    }

    pub fn index(&self) -> &Arc<RefIndex> {
        &self.index
    }

    pub fn reference_len(&self) -> usize {
        self.index.reference_len()
    }

    /// Answer one top-k query through the shared index and worker pool.
    ///
    /// Degenerate shapes degrade to short results instead of errors: a
    /// query longer than the reference has zero candidate windows and
    /// returns an empty `matches` list; `k` beyond the candidate count
    /// returns every window ranked.
    pub fn search_one(&self, q: &Query, k: usize) -> Result<TopKResult> {
        anyhow::ensure!(k >= 1, "k must be >= 1");
        anyhow::ensure!(!q.query.is_empty(), "empty query");
        validate_series("query", &q.query)?;
        q.metric.validate()?;
        if q.query.len() > self.index.reference_len() {
            return Ok(TopKResult { matches: Vec::new(), counters: Counters::new() });
        }
        let w = q
            .metric
            .effective_window(q.query.len(), window_cells(q.query.len(), q.window_ratio));
        let mut pre = Counters::new();
        let (stats, denv) =
            self.index.artifacts_for(q.query.len(), w, q.metric, self.suite, &mut pre)?;
        let (matches, mut counters) = route_query_topk(
            &self.senders,
            self.index.reference(),
            &q.query,
            w,
            q.metric,
            self.suite,
            self.scan_mode,
            k,
            self.sync_every,
            self.tuning,
            denv,
            Some(stats),
        )?;
        counters.merge(&pre);
        Ok(TopKResult { matches, counters })
    }

    /// Answer a batch of top-k queries, reusing the index across the
    /// whole batch.
    ///
    /// **Result-ordering contract:** the returned vector aligns
    /// index-for-index with `queries` — `results[i]` always answers
    /// `queries[i]` — even though [`BatchMode::Cohort`] groups same-shape
    /// queries into cohorts and evaluates them out of input order
    /// (property-tested on mixed-length batches in
    /// `tests/conformance_cohort.rs`). Results are also bitwise-identical
    /// to `queries.len()` independent [`Engine::search_one`] calls in
    /// either batch mode.
    pub fn search_batch(&self, queries: &[Query], k: usize) -> Result<Vec<TopKResult>> {
        match (self.batch, self.scan_mode) {
            // the cohort scan is strip-major by construction: a
            // scalar-mode engine serves batches sequentially
            (BatchMode::Sequential, _) | (_, ScanMode::Scalar) => {
                self.search_batch_sequential(queries, k)
            }
            (BatchMode::Cohort, ScanMode::Strip) => self.search_batch_cohort(queries, k),
        }
    }

    /// The query-major A/B baseline: every query an independent
    /// [`Engine::search_one`] fan-out, streaming the reference once per
    /// query. Same results (bitwise) and the same index-for-index
    /// ordering contract as [`Engine::search_batch`].
    pub fn search_batch_sequential(&self, queries: &[Query], k: usize) -> Result<Vec<TopKResult>> {
        queries.iter().map(|q| self.search_one(q, k)).collect()
    }

    /// Strip-major batch serving: group `queries` into cohorts of equal
    /// (length, window, metric), run each cohort as one shared strip pass
    /// over the reference, and scatter the per-query results back to
    /// input order. Singleton cohorts take the [`Engine::search_one`]
    /// path verbatim.
    fn search_batch_cohort(&self, queries: &[Query], k: usize) -> Result<Vec<TopKResult>> {
        anyhow::ensure!(k >= 1, "k must be >= 1");
        // admission-check the whole batch up front so a malformed late
        // query cannot leave earlier cohorts half-served
        for q in queries {
            anyhow::ensure!(!q.query.is_empty(), "empty query");
            validate_series("query", &q.query)?;
            q.metric.validate()?;
        }
        let mut results: Vec<Option<TopKResult>> = queries.iter().map(|_| None).collect();
        // cohort key: (query length, effective window, metric) — suite and
        // scan mode are engine-wide. Batches are small: linear grouping.
        let mut cohorts: Vec<(usize, usize, Metric, Vec<usize>)> = Vec::new();
        for (qi, q) in queries.iter().enumerate() {
            if q.query.len() > self.index.reference_len() {
                // zero candidate windows: the search_one degenerate answer
                results[qi] =
                    Some(TopKResult { matches: Vec::new(), counters: Counters::new() });
                continue;
            }
            let n = q.query.len();
            let w = q.metric.effective_window(n, window_cells(n, q.window_ratio));
            match cohorts
                .iter_mut()
                .find(|(cn, cw, cm, _)| *cn == n && *cw == w && *cm == q.metric)
            {
                Some((_, _, _, idxs)) => idxs.push(qi),
                None => cohorts.push((n, w, q.metric, vec![qi])),
            }
        }
        for (n, w, metric, idxs) in cohorts {
            if idxs.len() == 1 {
                let qi = idxs[0];
                results[qi] = Some(self.search_one(&queries[qi], k)?);
                continue;
            }
            // per-query index accounting, exactly as sequential serving:
            // the first member's lookup builds, the rest hit the cache
            let mut pres = Vec::with_capacity(idxs.len());
            let mut artifacts = None;
            for _ in &idxs {
                let mut pre = Counters::new();
                artifacts = Some(self.index.artifacts_for(n, w, metric, self.suite, &mut pre)?);
                pres.push(pre);
            }
            let (stats, denv) = artifacts.expect("cohort has members");
            let qrefs: Vec<&[f64]> =
                idxs.iter().map(|&qi| queries[qi].query.as_slice()).collect();
            let per_query = route_cohort_topk(
                &self.senders,
                self.index.reference(),
                &qrefs,
                w,
                metric,
                self.suite,
                k,
                self.sync_every,
                self.tuning,
                denv,
                stats,
            )?;
            for ((&qi, (matches, mut counters)), pre) in
                idxs.iter().zip(per_query).zip(pres)
            {
                counters.merge(&pre);
                results[qi] = Some(TopKResult { matches, counters });
            }
        }
        Ok(results.into_iter().map(|r| r.expect("every query answered")).collect())
    }

    /// Workers currently scanning.
    pub fn busy_workers(&self) -> u64 {
        self.busy.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The scan front-end this engine's shard workers run.
    pub fn scan_mode(&self) -> ScanMode {
        self.scan_mode
    }

    /// The batch front-end [`Engine::search_batch`] uses.
    pub fn batch_mode(&self) -> BatchMode {
        self.batch
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.senders.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{extract_queries, Dataset};
    use crate::search::subsequence::search_subsequence;

    #[test]
    fn batch_k1_matches_direct_search() {
        let r = Dataset::Ecg.generate(3000, 8);
        let qs: Vec<Query> = extract_queries(&r, 3, 128, 0.1, 9)
            .into_iter()
            .map(|q| Query::new(q, 0.1))
            .collect();
        let engine = Engine::new(r.clone(), &EngineConfig::default()).unwrap();
        let results = engine.search_batch(&qs, 1).unwrap();
        for (q, res) in qs.iter().zip(&results) {
            let mut c = Counters::new();
            let want =
                search_subsequence(&r, &q.query, window_cells(q.query.len(), 0.1), Suite::UcrMon, &mut c);
            assert_eq!(res.matches.len(), 1);
            assert_eq!(res.best().pos, want.pos);
            assert!((res.best().dist - want.dist).abs() < 1e-9);
            assert_eq!(res.counters.candidates, c.candidates);
        }
        // batch of 3 same-shape queries: stats + envelopes built once,
        // then served from cache
        let (hits, misses) = engine.index().hit_counts();
        assert_eq!(misses, 2, "one stats bucket + one envelope build");
        assert_eq!(hits, 4, "two later queries x two artifacts");
    }

    #[test]
    fn cohort_batch_is_bitwise_identical_to_sequential_batch() {
        let r = Dataset::Ecg.generate(2600, 12);
        let qs: Vec<Query> = extract_queries(&r, 5, 128, 0.1, 13)
            .into_iter()
            .map(|q| Query::new(q, 0.1))
            .collect();
        let engine = Engine::new(r, &EngineConfig { shards: 3, ..Default::default() }).unwrap();
        assert_eq!(engine.batch_mode(), BatchMode::Cohort);
        let cohort = engine.search_batch(&qs, 4).unwrap();
        let seq = engine.search_batch_sequential(&qs, 4).unwrap();
        assert_eq!(cohort.len(), seq.len());
        let mut tot = Counters::new();
        for (a, b) in cohort.iter().zip(&seq) {
            assert_eq!(a.matches.len(), b.matches.len());
            for (x, y) in a.matches.iter().zip(&b.matches) {
                assert_eq!(x.pos, y.pos);
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
            }
            // per-member work equals the sequential scan's (no retirement
            // on noisy queries) — only *where the stats came from* differs
            assert_eq!(a.counters.candidates, b.counters.candidates);
            tot.merge(&a.counters);
        }
        // shared-strip accounting balances exactly: loads performed +
        // loads saved = the loads a sequential batch makes
        assert!(tot.cohort_strips > 0);
        assert!(tot.strip_stat_loads_saved > 0);
        assert_eq!(
            tot.strip_stat_loads_saved * qs.len() as u64,
            tot.candidates * (qs.len() as u64 - 1)
        );
        assert_eq!(tot.cohort_retired_queries, 0);
    }

    #[test]
    fn scalar_engine_serves_batches_sequentially() {
        // a scalar-mode engine has no strip pipeline to share: batches
        // fall back to the sequential path and still answer correctly
        let r = Dataset::Ppg.generate(1400, 7);
        let qs: Vec<Query> = extract_queries(&r, 3, 96, 0.1, 8)
            .into_iter()
            .map(|q| Query::new(q, 0.1))
            .collect();
        let engine = Engine::new(
            r,
            &EngineConfig { scan_mode: ScanMode::Scalar, ..Default::default() },
        )
        .unwrap();
        let results = engine.search_batch(&qs, 2).unwrap();
        for (q, res) in qs.iter().zip(&results) {
            assert_eq!(res.counters.cohort_strips, 0, "no cohort scan ran");
            let want = engine.search_one(q, 2).unwrap();
            for (x, y) in res.matches.iter().zip(&want.matches) {
                assert_eq!(x.pos, y.pos);
                assert_eq!(x.dist.to_bits(), y.dist.to_bits());
            }
        }
    }

    #[test]
    fn topk_results_are_sorted_and_distinct() {
        let r = Dataset::Ppg.generate(2500, 4);
        let q = Query::new(extract_queries(&r, 1, 128, 0.1, 5).remove(0), 0.2);
        let engine = Engine::new(r, &EngineConfig { shards: 3, ..Default::default() }).unwrap();
        let res = engine.search_one(&q, 8).unwrap();
        assert_eq!(res.matches.len(), 8);
        for pair in res.matches.windows(2) {
            assert!(pair[0].dist <= pair[1].dist);
            assert_ne!(pair[0].pos, pair[1].pos);
        }
        assert!(res.counters.topk_updates >= 8);
    }

    #[test]
    fn rejects_bad_inputs() {
        let engine = Engine::new(Dataset::Ecg.generate(500, 1), &EngineConfig::default()).unwrap();
        assert!(engine.search_one(&Query::new(vec![], 0.1), 1).is_err());
        assert!(engine.search_one(&Query::new(vec![0.0; 64], 0.1), 0).is_err());
        // invalid metric parameters are an error, not a NaN poisoning the
        // worker pool's heaps
        let bad = Metric::Twe { nu: f64::NAN, lambda: 1.0 };
        assert!(engine.search_one(&Query::with_metric(vec![0.0; 64], 0.1, bad), 1).is_err());
        // a NaN / inf query point is a graceful error, not a shard panic
        let mut q = vec![0.5; 64];
        q[10] = f64::NAN;
        assert!(engine.search_one(&Query::new(q.clone(), 0.1), 1).is_err());
        q[10] = f64::INFINITY;
        assert!(engine.search_one(&Query::new(q, 0.1), 1).is_err());
        // …and a NaN reference is rejected at construction
        let mut r = Dataset::Ecg.generate(300, 2);
        r[5] = f64::NAN;
        assert!(Engine::new(r, &EngineConfig::default()).is_err());
    }

    #[test]
    fn scalar_and_strip_engines_agree_bitwise() {
        let r = Dataset::Pamap2.generate(2200, 41);
        let q = Query::new(extract_queries(&r, 1, 128, 0.1, 42).remove(0), 0.1);
        let scalar = Engine::new(
            r.clone(),
            &EngineConfig { shards: 2, scan_mode: ScanMode::Scalar, ..Default::default() },
        )
        .unwrap();
        let strip = Engine::new(
            r,
            &EngineConfig { shards: 2, scan_mode: ScanMode::Strip, ..Default::default() },
        )
        .unwrap();
        assert_eq!(strip.scan_mode(), ScanMode::Strip);
        let a = scalar.search_one(&q, 7).unwrap();
        let b = strip.search_one(&q, 7).unwrap();
        assert_eq!(a.matches.len(), b.matches.len());
        for (x, y) in a.matches.iter().zip(&b.matches) {
            assert_eq!(x.pos, y.pos);
            assert_eq!(x.dist.to_bits(), y.dist.to_bits());
        }
        assert!(b.counters.strip_batches > 0);
        assert_eq!(a.counters.strip_batches, 0);
    }

    #[test]
    fn query_longer_than_reference_returns_empty_ranked_list() {
        // zero candidate windows is a short answer, not an error or panic
        let engine = Engine::new(Dataset::Ecg.generate(500, 1), &EngineConfig::default()).unwrap();
        let res = engine.search_batch(&[Query::new(vec![0.0; 1000], 0.1)], 3).unwrap();
        assert_eq!(res.len(), 1);
        assert!(res[0].matches.is_empty());
        assert_eq!(res[0].counters.candidates, 0);
    }

    #[test]
    fn k_beyond_candidate_count_returns_all_windows_ranked() {
        let r = Dataset::Ppg.generate(140, 2);
        let engine = Engine::new(r.clone(), &EngineConfig::default()).unwrap();
        let q = Query::new(r[5..133].to_vec(), 0.1);
        let windows = r.len() - 128 + 1;
        let res = engine.search_one(&q, 10_000).unwrap();
        assert_eq!(res.matches.len(), windows);
        for pair in res.matches.windows(2) {
            assert!(
                pair[0].dist < pair[1].dist
                    || (pair[0].dist == pair[1].dist && pair[0].pos < pair[1].pos)
            );
        }
    }

    #[test]
    fn metric_queries_route_through_engine_and_skip_envelopes() {
        use crate::search::subsequence::search_subsequence_topk_metric;
        let r = Dataset::Refit.generate(1500, 19);
        let q = extract_queries(&r, 1, 64, 0.1, 20).remove(0);
        let metric = Metric::Msm { cost: 0.5 };
        let engine =
            Engine::new(r.clone(), &EngineConfig { shards: 1, ..Default::default() }).unwrap();
        let res = engine.search_one(&Query::with_metric(q.clone(), 0.1, metric), 4).unwrap();
        let mut c = Counters::new();
        let want = search_subsequence_topk_metric(
            &r,
            &q,
            window_cells(q.len(), 0.1),
            4,
            metric,
            Suite::UcrMon,
            &mut c,
        );
        assert_eq!(res.matches.len(), want.len());
        for (g, m) in res.matches.iter().zip(&want) {
            assert_eq!(g.pos, m.pos);
            assert!((g.dist - m.dist).abs() < 1e-9);
        }
        // per-metric tallies survived the shard fan-in...
        assert_eq!(res.counters.metric_calls[metric.index()], res.counters.dtw_calls);
        // ...and no envelope artifact was ever built for a non-DTW metric
        let (_, misses) = engine.index().hit_counts();
        assert_eq!(misses, 1, "stats bucket only, no envelopes");
    }
}
