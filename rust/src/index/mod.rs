//! Reference-side index + top-k multi-query search engine (system S15).
//!
//! The paper's UCR-style loop does all reference-side work per query:
//! candidate stats are streamed, data envelopes rebuilt, and a single
//! scalar best-so-far drives early abandoning. Once EAPrunedDTW makes the
//! query-side cheap (paper §5), that per-query reference work dominates a
//! serving workload. This layer amortises it:
//!
//! * [`ref_index::RefIndex`] — per-position window stats (one table per
//!   query-length bucket) and raw-stream envelopes for the reversed
//!   LB_Keogh "EC" bound, computed once per reference and shared
//!   read-only across queries, batches and shard workers.
//! * [`topk::TopK`] — a bounded max-heap of the k best matches whose k-th
//!   distance replaces the scalar best-so-far as the early-abandon
//!   threshold threaded through the cascade and the DTW cores.
//! * [`engine::Engine`] — the batched multi-query front end:
//!   [`engine::Engine::search_batch`] answers a batch of top-k queries
//!   over one shared index, fanning each query out across the coordinator
//!   shard workers.

pub mod engine;
pub mod ref_index;
pub mod topk;

pub use engine::{BatchMode, Engine, EngineConfig, Query, TopKResult};
pub use ref_index::{BucketStats, RefIndex};
pub use topk::TopK;
