//! The reference-side index: everything about one reference stream that is
//! query-independent, computed once and shared read-only across queries,
//! batches and shard workers.
//!
//! Two artifact families live here, keyed by the only two query parameters
//! they depend on:
//!
//! * [`BucketStats`] — per-position window mean/std for one query-length
//!   bucket, so candidate z-normalisation needs no streaming state. The
//!   table is built with the *same* running-sum recurrence (including the
//!   periodic refresh) as [`crate::norm::znorm::WindowStats`] scanning
//!   from position 0, so an indexed scan is bit-identical to the seed's
//!   full streaming scan — and, unlike streaming, independent of where
//!   shard boundaries fall.
//! * Reference envelopes for one warping-window size — the Lemire
//!   envelopes of the *raw* stream that the reversed LB_Keogh "EC" bound
//!   consumes ([`crate::search::subsequence::DataEnvelopes`]). The seed
//!   recomputed these O(ref_len) arrays per query; the index computes them
//!   once per window size and hands out `Arc`s.
//!
//! Both caches fill lazily and count hits into
//! [`Counters::index_hits`](crate::metrics::Counters), so the serving
//! layer can report how much reference-side work the index amortised.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::distances::metric::Metric;
use crate::metrics::Counters;
use crate::norm::znorm::WindowStats;
use crate::search::subsequence::DataEnvelopes;
use crate::search::suite::Suite;

/// Per-position (mean, std) of every window of one length over the
/// reference — the z-norm statistics table for one query-length bucket.
#[derive(Debug, Clone)]
pub struct BucketStats {
    qlen: usize,
    mean: Vec<f64>,
    std: Vec<f64>,
}

impl BucketStats {
    /// Build the table for windows of `qlen` points. Panics if the
    /// reference is shorter than `qlen` or `qlen == 0` (as
    /// [`WindowStats::new`] does).
    pub fn build(reference: &[f64], qlen: usize) -> Self {
        let mut ws = WindowStats::new(reference, qlen);
        let total = reference.len() - qlen + 1;
        let mut mean = Vec::with_capacity(total);
        let mut std = Vec::with_capacity(total);
        loop {
            let (m, s) = ws.mean_std();
            mean.push(m);
            std.push(s);
            if !ws.advance() {
                break;
            }
        }
        debug_assert_eq!(mean.len(), total);
        Self { qlen, mean, std }
    }

    /// Window length this bucket serves.
    pub fn qlen(&self) -> usize {
        self.qlen
    }

    /// Number of candidate positions covered.
    pub fn positions(&self) -> usize {
        self.mean.len()
    }

    /// (mean, std) of the window starting at `pos`.
    #[inline]
    pub fn mean_std(&self, pos: usize) -> (f64, f64) {
        (self.mean[pos], self.std[pos])
    }

    /// SoA view of `len` consecutive positions starting at `pos` — the
    /// strip-mined scan copies these lanes into its scratch buffers in
    /// one pass instead of making `len` scalar [`BucketStats::mean_std`]
    /// calls.
    #[inline]
    pub fn strip(&self, pos: usize, len: usize) -> (&[f64], &[f64]) {
        (&self.mean[pos..pos + len], &self.std[pos..pos + len])
    }
}

/// Shared, read-only reference-side index: one per reference stream,
/// `Arc`-shared by every query, batch and shard worker that scans it.
#[derive(Debug)]
pub struct RefIndex {
    reference: Arc<Vec<f64>>,
    stats: RwLock<BTreeMap<usize, Arc<BucketStats>>>,
    envelopes: RwLock<BTreeMap<usize, Arc<DataEnvelopes>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl RefIndex {
    /// Cache cap per artifact family. Query lengths and window sizes are
    /// client-controlled; past this many distinct keys, new artifacts are
    /// built per call and *not* retained, so a scan over many shapes
    /// cannot grow the index without bound (real workloads use a handful
    /// of length buckets, which stay cached).
    pub const MAX_CACHED: usize = 32;

    pub fn new(reference: Arc<Vec<f64>>) -> Self {
        Self {
            reference,
            stats: RwLock::new(BTreeMap::new()),
            envelopes: RwLock::new(BTreeMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The indexed reference stream.
    pub fn reference(&self) -> &Arc<Vec<f64>> {
        &self.reference
    }

    pub fn reference_len(&self) -> usize {
        self.reference.len()
    }

    /// Cache hits / misses over both artifact families since construction.
    pub fn hit_counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    fn record(&self, hit: bool, counters: &mut Counters) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
            counters.index_hits += 1;
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The window-stats table for query length `qlen`, building it on
    /// first use. Errors on a degenerate bucket instead of panicking.
    pub fn stats_for(&self, qlen: usize, counters: &mut Counters) -> Result<Arc<BucketStats>> {
        anyhow::ensure!(qlen > 0, "empty query length bucket");
        anyhow::ensure!(
            self.reference.len() >= qlen,
            "reference ({} points) shorter than query ({qlen})",
            self.reference.len()
        );
        if let Some(t) = self.stats.read().expect("stats lock").get(&qlen) {
            self.record(true, counters);
            return Ok(Arc::clone(t));
        }
        // build outside any lock: O(ref_len), and concurrent builders of
        // the same bucket produce identical tables (first insert wins)
        let built = Arc::new(BucketStats::build(&self.reference, qlen));
        let mut map = self.stats.write().expect("stats lock");
        let out = if map.len() < Self::MAX_CACHED || map.contains_key(&qlen) {
            Arc::clone(map.entry(qlen).or_insert(built))
        } else {
            built
        };
        drop(map);
        self.record(false, counters);
        Ok(out)
    }

    /// The reference-side artifacts one query needs, metric-aware: the
    /// window-stats bucket for its length always (every metric z-normalises
    /// candidates), the raw-stream envelopes only when both the suite's
    /// cascade *and* the query's metric can use them — so an ERP/MSM/TWE/
    /// WDTW query never triggers (or pays for) a DTW envelope build.
    pub fn artifacts_for(
        &self,
        qlen: usize,
        w: usize,
        metric: Metric,
        suite: Suite,
        counters: &mut Counters,
    ) -> Result<(Arc<BucketStats>, Option<Arc<DataEnvelopes>>)> {
        let stats = self.stats_for(qlen, counters)?;
        let denv = metric
            .wants_data_envelopes(suite)
            .then(|| self.envelopes_for(w, counters));
        Ok((stats, denv))
    }

    /// The raw-stream envelopes for warping window `w` (cells), building
    /// them on first use.
    pub fn envelopes_for(&self, w: usize, counters: &mut Counters) -> Arc<DataEnvelopes> {
        if let Some(e) = self.envelopes.read().expect("envelope lock").get(&w) {
            self.record(true, counters);
            return Arc::clone(e);
        }
        let built = Arc::new(DataEnvelopes::new(&self.reference, w));
        let mut map = self.envelopes.write().expect("envelope lock");
        let out = if map.len() < Self::MAX_CACHED || map.contains_key(&w) {
            Arc::clone(map.entry(w).or_insert(built))
        } else {
            built
        };
        drop(map);
        self.record(false, counters);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::norm::znorm::stats;

    #[test]
    fn bucket_stats_match_streaming_and_batch() {
        let r = Dataset::Ecg.generate(600, 11);
        let n = 48;
        let t = BucketStats::build(&r, n);
        assert_eq!(t.positions(), r.len() - n + 1);
        // bit-identical to the streaming scan it mirrors
        let mut ws = WindowStats::new(&r, n);
        loop {
            let (m, s) = ws.mean_std();
            let (tm, ts) = t.mean_std(ws.pos());
            assert_eq!(m, tm, "pos {}", ws.pos());
            assert_eq!(s, ts, "pos {}", ws.pos());
            if !ws.advance() {
                break;
            }
        }
        // and within fp tolerance of the batch oracle
        for pos in [0usize, 7, 100, r.len() - n] {
            let (bm, bs) = stats(&r[pos..pos + n]);
            let (tm, ts) = t.mean_std(pos);
            assert!((tm - bm).abs() < 1e-8);
            assert!((ts - bs).abs() < 1e-8);
        }
        // strip views are windows into the same lanes
        let (ms, ss) = t.strip(40, 64);
        assert_eq!(ms.len(), 64);
        for i in 0..64 {
            let (m, s) = t.mean_std(40 + i);
            assert_eq!(ms[i].to_bits(), m.to_bits());
            assert_eq!(ss[i].to_bits(), s.to_bits());
        }
    }

    #[test]
    fn caches_hit_on_reuse() {
        let r = Arc::new(Dataset::Ppg.generate(500, 3));
        let idx = RefIndex::new(r);
        let mut c = Counters::new();
        let a = idx.stats_for(32, &mut c).unwrap();
        assert_eq!(c.index_hits, 0);
        let b = idx.stats_for(32, &mut c).unwrap();
        assert_eq!(c.index_hits, 1);
        assert!(Arc::ptr_eq(&a, &b));
        let e1 = idx.envelopes_for(5, &mut c);
        let e2 = idx.envelopes_for(5, &mut c);
        assert!(Arc::ptr_eq(&e1, &e2));
        assert_eq!(c.index_hits, 2);
        assert_eq!(idx.hit_counts(), (2, 2));
    }

    #[test]
    fn envelopes_match_direct_construction() {
        let r = Arc::new(Dataset::FoG.generate(400, 9));
        let idx = RefIndex::new(Arc::clone(&r));
        let mut c = Counters::new();
        let e = idx.envelopes_for(7, &mut c);
        let want = DataEnvelopes::new(&r, 7);
        assert_eq!(e.upper, want.upper);
        assert_eq!(e.lower, want.lower);
    }

    #[test]
    fn cache_stops_growing_at_cap() {
        let r = Arc::new(Dataset::Soccer.generate(400, 5));
        let idx = RefIndex::new(r);
        let mut c = Counters::new();
        for qlen in 2..(RefIndex::MAX_CACHED + 50) {
            idx.stats_for(qlen, &mut c).unwrap();
        }
        // every key past the cap was served uncached (a repeat is a miss)
        let over = RefIndex::MAX_CACHED + 10;
        let (hits_before, _) = idx.hit_counts();
        idx.stats_for(over, &mut c).unwrap();
        assert_eq!(idx.hit_counts().0, hits_before, "over-cap key must not be cached");
        // …while keys below the cap still hit
        idx.stats_for(2, &mut c).unwrap();
        assert_eq!(idx.hit_counts().0, hits_before + 1);
    }

    #[test]
    fn artifacts_are_metric_aware() {
        let r = Arc::new(Dataset::Ecg.generate(400, 8));
        let idx = RefIndex::new(r);
        let mut c = Counters::new();
        // a non-DTW metric must not build envelopes
        let (stats, denv) =
            idx.artifacts_for(64, 6, Metric::Erp { gap: 0.0 }, Suite::UcrMon, &mut c).unwrap();
        assert_eq!(stats.qlen(), 64);
        assert!(denv.is_none());
        assert_eq!(idx.hit_counts(), (0, 1), "stats bucket only");
        // the DTW default builds (and caches) them
        let (_, denv) = idx.artifacts_for(64, 6, Metric::Cdtw, Suite::UcrMon, &mut c).unwrap();
        assert!(denv.is_some());
        assert_eq!(idx.hit_counts(), (1, 2), "stats hit + envelope build");
        // a bound-free suite skips envelopes even for cDTW
        let (_, denv) = idx.artifacts_for(64, 6, Metric::Cdtw, Suite::UcrMonNoLb, &mut c).unwrap();
        assert!(denv.is_none());
    }

    #[test]
    fn degenerate_buckets_error() {
        let idx = RefIndex::new(Arc::new(vec![0.0; 10]));
        let mut c = Counters::new();
        assert!(idx.stats_for(0, &mut c).is_err());
        assert!(idx.stats_for(11, &mut c).is_err());
        assert!(idx.stats_for(10, &mut c).is_ok());
    }
}
