//! Bounded top-k result collector: a max-heap of the k best matches whose
//! worst (k-th best) distance is the search's early-abandon threshold.
//!
//! This generalises the scalar best-so-far of the UCR loop: with `k = 1`
//! the collector *is* a best-so-far — [`TopK::offer`] accepts exactly when
//! the scalar update `d < bsf` would have fired, and [`TopK::threshold`]
//! returns exactly what the scalar `bsf` would hold — so every k = 1 path
//! is bit-identical to the seed behaviour (property-tested in
//! `tests/integration_index.rs`).
//!
//! Tie handling follows the seed convention: `offer` requires a *strict*
//! improvement, so in an ascending-position scan the earliest position
//! wins a distance tie. Cross-shard merges sort by `(dist, pos)` instead
//! ([`TopK::merge`]), which resolves ties deterministically in favour of
//! the smaller position — the same rule the router always used.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::search::subsequence::Match;

/// Heap entry ordered worst-first: larger distance is "greater", and on an
/// exact distance tie the larger position is "greater" (evicted first).
#[derive(Debug, Clone, Copy)]
struct Worst(Match);

impl PartialEq for Worst {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Worst {}
impl PartialOrd for Worst {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Worst {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0
            .dist
            .partial_cmp(&other.0.dist)
            .expect("no NaN distances")
            .then(self.0.pos.cmp(&other.0.pos))
    }
}

/// Bounded collector of the k best (smallest-distance) matches seen so
/// far, with an optional external upper bound (the serving layer's shared
/// global threshold) folded into the abandon cutoff.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    /// external cutoff: results at or above it can never be accepted
    bound: f64,
    heap: BinaryHeap<Worst>,
    /// cached "threshold reached 0" flag, maintained at every mutation so
    /// the cohort scan's per-strip retirement check is a plain bool read
    /// instead of a heap peek per strip per member
    exhausted: bool,
}

impl TopK {
    /// Collector for the k best matches, unbounded from above.
    pub fn new(k: usize) -> Self {
        Self::with_bound(k, f64::INFINITY)
    }

    /// Collector whose cutoff starts at `bound` (pass the incoming
    /// best-so-far when resuming a scan). Panics if `k == 0`. The heap
    /// grows on demand, so a large k costs nothing until results arrive
    /// (callers clamp k to the candidate count; a hostile k must not
    /// pre-allocate).
    pub fn with_bound(k: usize, bound: f64) -> Self {
        assert!(k >= 1, "top-k needs k >= 1");
        Self {
            k,
            bound,
            heap: BinaryHeap::with_capacity(k.min(1024) + 1),
            exhausted: bound <= 0.0,
        }
    }

    /// Re-derive the cached exhaustion flag; called after every mutation
    /// that can tighten the threshold (acceptance, bound update, merge).
    /// Monotone: once true it stays true, because the threshold never
    /// loosens.
    #[inline]
    fn refresh_exhausted(&mut self) {
        if !self.exhausted {
            self.exhausted = self.threshold() <= 0.0;
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Is the collector holding k results already?
    pub fn is_full(&self) -> bool {
        self.heap.len() == self.k
    }

    /// The current early-abandon cutoff: the k-th best distance once k
    /// results are held, the external bound before that (a not-yet-full
    /// collector must not discard anything below the external bound).
    #[inline]
    pub fn threshold(&self) -> f64 {
        if self.heap.len() < self.k {
            self.bound
        } else {
            let kth = self.heap.peek().expect("full heap").0.dist;
            kth.min(self.bound)
        }
    }

    /// The k-th best distance held, if the collector is full. This is the
    /// value a shard publishes to the shared global threshold: the union
    /// of all shards' results has at least k entries at or below it, so
    /// it is a valid global cutoff.
    pub fn kth_dist(&self) -> Option<f64> {
        if self.is_full() {
            self.heap.peek().map(|w| w.0.dist)
        } else {
            None
        }
    }

    /// Can this collector never accept another result from candidates at
    /// *later positions* than everything already held? Distances are
    /// non-negative and acceptance is strict (`<` the threshold), so once
    /// the threshold reaches 0 nothing can enter via the improvement arm;
    /// the tie arm additionally needs a *smaller* position than a held
    /// entry, which a forward scan can no longer produce. The cohort scan
    /// checks this at strip boundaries to retire a query mid-scan; the
    /// answer is cached on the collector (`k`-th == 0 is a one-way state),
    /// so the check costs a bool read, not a heap re-scan per strip.
    #[inline]
    pub fn exhausted(&self) -> bool {
        debug_assert_eq!(self.exhausted, self.threshold() <= 0.0, "stale exhausted cache");
        self.exhausted
    }

    /// Lower the external bound (monotone: a looser value is ignored).
    pub fn set_bound(&mut self, bound: f64) {
        if bound < self.bound {
            self.bound = bound;
            self.refresh_exhausted();
        }
    }

    /// Offer a match; accepted iff it *strictly* beats the current
    /// threshold (the scalar `d < bsf` rule — which also rejects NaN, as
    /// the seed's `d < bsf` comparison did; a NaN inside the heap would
    /// poison its ordering), or iff it ties the k-th best distance
    /// exactly at a *smaller position*. Returns whether it was kept.
    ///
    /// The tie arm makes the collector's final contents independent of
    /// offer order: the result is always the k lexicographically smallest
    /// `(dist, pos)` pairs offered. In an ascending-position scan the arm
    /// can never fire (a later candidate's position exceeds every heap
    /// entry's), so every seed k = 1 / ascending-scan path keeps its
    /// bit-identical behaviour — but out-of-order visitors (NN1's
    /// best-first order, the strip scan's LB-ordered survivors) now
    /// resolve distance ties exactly like the position-ordered scan.
    ///
    /// Order-independence is per collector: a tie with the *external*
    /// bound (another shard's published k-th best) is still rejected,
    /// exactly as the seed did, so cross-shard exact-tie resolution keeps
    /// the router's documented timing caveat
    /// (see [`crate::coordinator::router::route_query_topk`]).
    pub fn offer(&mut self, m: Match) -> bool {
        if m.dist.is_nan() {
            return false;
        }
        if m.dist < self.threshold() {
            if self.is_full() {
                self.heap.pop();
            }
            self.heap.push(Worst(m));
            self.refresh_exhausted();
            return true;
        }
        // exact tie with the k-th best at a smaller position (still
        // strictly below the external bound: results at or above the
        // bound are someone else's)
        if self.is_full() && m.dist < self.bound {
            let worst = self.heap.peek().expect("full heap").0;
            if m.dist == worst.dist && m.pos < worst.pos {
                self.heap.pop();
                self.heap.push(Worst(m));
                // the k-th distance is unchanged (same dist, new pos), so
                // the exhaustion state cannot have flipped — refresh is
                // still cheap and keeps the invariant local
                self.refresh_exhausted();
                return true;
            }
        }
        false
    }

    /// Fold another collector's results in, re-ranking by `(dist, pos)` so
    /// the outcome is independent of merge order (cross-shard ties go to
    /// the smaller position, the router's historical rule).
    pub fn merge(&mut self, other: TopK) {
        let mut all: Vec<Worst> = self.heap.drain().collect();
        all.extend(other.heap);
        all.sort();
        all.truncate(self.k);
        self.heap.extend(all);
        self.refresh_exhausted();
    }

    /// Results in ascending `(dist, pos)` order, consuming the collector.
    pub fn into_sorted(self) -> Vec<Match> {
        self.heap.into_sorted_vec().into_iter().map(|w| w.0).collect()
    }

    /// Results in ascending `(dist, pos)` order, without consuming.
    pub fn to_sorted(&self) -> Vec<Match> {
        self.clone().into_sorted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(pos: usize, dist: f64) -> Match {
        Match { pos, dist }
    }

    #[test]
    fn k1_behaves_like_best_so_far() {
        let mut t = TopK::new(1);
        assert_eq!(t.threshold(), f64::INFINITY);
        assert!(t.offer(m(5, 3.0)));
        assert_eq!(t.threshold(), 3.0);
        // equal distance at a later position is rejected (strict <)
        assert!(!t.offer(m(9, 3.0)));
        assert!(t.offer(m(2, 1.0)));
        assert_eq!(t.into_sorted(), vec![m(2, 1.0)]);
    }

    #[test]
    fn keeps_k_smallest_in_order() {
        let mut t = TopK::new(3);
        for (pos, dist) in [(0, 5.0), (1, 2.0), (2, 9.0), (3, 1.0), (4, 4.0)] {
            t.offer(m(pos, dist));
        }
        assert_eq!(t.into_sorted(), vec![m(3, 1.0), m(1, 2.0), m(4, 4.0)]);
    }

    #[test]
    fn threshold_stays_at_bound_until_full() {
        let mut t = TopK::with_bound(2, 10.0);
        assert!(t.offer(m(0, 8.0)));
        // one slot free: the external bound still rules
        assert_eq!(t.threshold(), 10.0);
        assert!(t.offer(m(1, 9.5)));
        assert_eq!(t.threshold(), 9.5);
        assert_eq!(t.kth_dist(), Some(9.5));
        // nothing at/above the cutoff enters
        assert!(!t.offer(m(2, 9.5)));
        assert!(t.offer(m(2, 0.5)));
        assert_eq!(t.into_sorted(), vec![m(2, 0.5), m(0, 8.0)]);
    }

    #[test]
    fn external_bound_caps_acceptance() {
        let mut t = TopK::with_bound(4, 2.0);
        assert!(!t.offer(m(0, 2.0)));
        assert!(!t.offer(m(0, 3.0)));
        assert!(t.offer(m(1, 1.0)));
        t.set_bound(0.5);
        assert!(!t.offer(m(2, 0.75)));
        // loosening is ignored
        t.set_bound(100.0);
        assert!(!t.offer(m(3, 0.75)));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn merge_is_order_independent_and_tie_breaks_by_pos() {
        let mut a = TopK::new(2);
        a.offer(m(10, 1.0));
        a.offer(m(11, 3.0));
        let mut b = TopK::new(2);
        b.offer(m(4, 3.0));
        b.offer(m(5, 2.0));
        let mut ab = a.clone();
        ab.merge(b.clone());
        let mut ba = b;
        ba.merge(a);
        assert_eq!(ab.to_sorted(), ba.to_sorted());
        // 1.0@10, then 2.0@5 — the 3.0 tie pair is cut entirely
        assert_eq!(ab.into_sorted(), vec![m(10, 1.0), m(5, 2.0)]);
    }

    #[test]
    fn tie_at_kth_swaps_in_the_smaller_position() {
        let mut t = TopK::new(2);
        assert!(t.offer(m(5, 1.0)));
        assert!(t.offer(m(8, 3.0)));
        // equal distance, larger position: rejected (ascending-scan rule)
        assert!(!t.offer(m(9, 3.0)));
        // equal distance, smaller position: replaces the k-th entry, so
        // the outcome matches what an ascending-position scan would hold
        assert!(t.offer(m(2, 3.0)));
        assert_eq!(t.into_sorted(), vec![m(5, 1.0), m(2, 3.0)]);
    }

    #[test]
    fn final_set_is_offer_order_independent() {
        let offers = [m(7, 2.0), m(3, 2.0), m(9, 1.0), m(1, 2.0), m(4, 5.0)];
        let mut fwd = TopK::new(2);
        for o in offers {
            fwd.offer(o);
        }
        let mut rev = TopK::new(2);
        for o in offers.iter().rev() {
            rev.offer(*o);
        }
        // k smallest by (dist, pos) either way
        assert_eq!(fwd.into_sorted(), vec![m(9, 1.0), m(1, 2.0)]);
        assert_eq!(rev.into_sorted(), vec![m(9, 1.0), m(1, 2.0)]);
    }

    #[test]
    fn tie_never_crosses_the_external_bound() {
        let mut t = TopK::with_bound(1, 3.0);
        assert!(!t.offer(m(5, 3.0)));
        assert!(t.offer(m(5, 2.0)));
        t.set_bound(2.0);
        // d == kth == bound: at the bound, not below it — rejected
        assert!(!t.offer(m(1, 2.0)));
        assert_eq!(t.into_sorted(), vec![m(5, 2.0)]);
    }

    #[test]
    fn exhausted_once_threshold_reaches_zero() {
        let mut t = TopK::new(2);
        assert!(!t.exhausted());
        t.offer(m(3, 0.0));
        assert!(!t.exhausted(), "one slot still free");
        t.offer(m(7, 0.0));
        assert!(t.exhausted(), "k-th best is 0: nothing later can enter");
        // a zero external bound exhausts even an empty collector
        let mut e = TopK::with_bound(4, 0.0);
        assert!(e.exhausted());
        assert!(!e.offer(m(0, 0.0)));
    }

    #[test]
    fn kth_dist_only_when_full() {
        let mut t = TopK::new(2);
        assert_eq!(t.kth_dist(), None);
        t.offer(m(0, 1.0));
        assert_eq!(t.kth_dist(), None);
        t.offer(m(1, 2.0));
        assert_eq!(t.kth_dist(), Some(2.0));
    }

    #[test]
    #[should_panic(expected = "k >= 1")]
    fn zero_k_panics() {
        TopK::new(0);
    }

    #[test]
    fn nan_distance_is_rejected_not_stored() {
        let mut t = TopK::new(2);
        assert!(!t.offer(m(0, f64::NAN)));
        assert!(t.offer(m(1, 1.0)));
        assert!(!t.offer(m(2, f64::NAN)));
        assert_eq!(t.into_sorted(), vec![m(1, 1.0)]);
    }

    #[test]
    fn huge_k_does_not_preallocate() {
        // a hostile k must not translate into a proportional allocation
        let mut t = TopK::new(usize::MAX / 2);
        assert!(t.offer(m(0, 1.0)));
        assert_eq!(t.len(), 1);
    }
}
