#!/usr/bin/env python3
"""Compare two BENCH_*.json artifacts (or audit one) and fail on
counter-invariant violations.

Accepts the documents `bench_support::report::BenchJson` writes — a
top-level ``runs`` array whose rows carry a ``counters`` object, plus an
optional embedded metrics snapshot (pinned schema ``repro.metrics.v1``)
under ``stats`` — and also bare snapshot documents, as emitted by
``repro serve --stats-every N`` or the ``{"cmd":"stats"}`` wire request.

Checked identities (the same ones ``rust/tests/prop_invariants.rs``
property-tests in-process; see ``rust/src/obs/README.md``):

    candidates == lb_kim_prunes + lb_keogh_eq_prunes
                  + lb_keogh_ec_prunes + lb_improved_prunes
                  + xla_prunes + dtw_calls
    dtw_calls  == dtw_abandons + dtw_completions
    dtw_calls  == sum(metric_calls_*)
    dtw_abandons == sum(metric_abandons_*)
    cost_model_rebuilds == 0

The robustness counters (``worker_panics``, ``worker_respawns``,
``shed_queries``, ``deadline_timeouts``) are absent in pre-robustness
artifacts and read as 0 there — those services could not have shed or
respawned. When present they must be non-negative integers, and the
two-file mode reports their deltas. The net front-end counters
(``conns_accepted``, ``conns_rejected``, ``conn_read_timeouts``,
``quota_shed_queries``) follow the same rule: absent in pre-net
artifacts (no TCP front-end existed) and read as 0 there. So do the
wavefront-kernel lane counters (``kernel_multi_calls``,
``kernel_lanes_filled``, ``kernel_lane_abandons``), which additionally
must satisfy ``kernel_lanes_filled >= 2 * kernel_multi_calls`` and
``kernel_lane_abandons <= kernel_lanes_filled``.

A counter absent from a document reads as unknown, and any identity
that needs it is skipped (older artifacts predate some counters);
present-but-inconsistent counters are hard failures.

Usage:
    bench_diff.py CURRENT.json                audit one artifact
    bench_diff.py BASELINE.json CURRENT.json  audit both + print deltas

Exit codes: 0 all invariants hold, 1 violation, 2 usage/parse error.
"""

import json
import sys

CASCADE_STAGES = (
    "lb_kim_prunes",
    "lb_keogh_eq_prunes",
    "lb_keogh_ec_prunes",
    "xla_prunes",
)
# stages added to the cascade after the original four: absent in older
# artifacts, where they read as 0 (those runs could not have pruned
# there) rather than as unknown
OPTIONAL_CASCADE_STAGES = ("lb_improved_prunes",)
# failure-model counters (supervision, admission, deadlines): absent in
# pre-robustness artifacts, where they read as 0 rather than as unknown
ROBUSTNESS_COUNTERS = (
    "worker_panics",
    "worker_respawns",
    "shed_queries",
    "deadline_timeouts",
)
# TCP front-end counters: absent in artifacts from before the net layer
# existed, where they read as 0 rather than as unknown
NET_COUNTERS = (
    "conns_accepted",
    "conns_rejected",
    "conn_read_timeouts",
    "quota_shed_queries",
)
# multi-candidate wavefront kernel counters: absent in artifacts from
# before lane packing existed, where they read as 0 rather than as
# unknown. A multi-lane call carries >= 2 lanes by definition and lane
# abandons are a subset of lanes filled, so when present:
#     kernel_lanes_filled  >= 2 * kernel_multi_calls
#     kernel_lane_abandons <= kernel_lanes_filled
LANE_COUNTERS = (
    "kernel_multi_calls",
    "kernel_lanes_filled",
    "kernel_lane_abandons",
)
# run-identity fields are everything except the measurements
MEASUREMENTS = {
    "seconds",
    "ns_per_op",
    "queries_per_sec",
    "ref_bytes_per_query",
    "lane_occupancy",
    "counters",
}


def _get(counters, *names):
    """Values for names, or None if any is absent from the document."""
    vals = []
    for n in names:
        v = counters.get(n)
        if v is None:
            return None
        vals.append(int(v))
    return vals


def check_counters(counters, where, problems):
    """Append a problem string per violated identity."""
    got = _get(counters, "candidates", "dtw_calls", *CASCADE_STAGES)
    if got is not None:
        cand, dtw = got[0], got[1]
        pruned = sum(got[2:])
        pruned += sum(int(counters.get(n, 0)) for n in OPTIONAL_CASCADE_STAGES)
        if cand != pruned + dtw:
            problems.append(
                f"{where}: candidates {cand} != stage prunes {pruned}"
                f" + dtw_calls {dtw}"
            )
    got = _get(counters, "dtw_calls", "dtw_abandons", "dtw_completions")
    if got is not None and got[0] != got[1] + got[2]:
        problems.append(
            f"{where}: dtw_calls {got[0]} != abandons {got[1]}"
            f" + completions {got[2]}"
        )
    for prefix, total_name in (
        ("metric_calls_", "dtw_calls"),
        ("metric_abandons_", "dtw_abandons"),
    ):
        per_metric = {k: int(v) for k, v in counters.items() if k.startswith(prefix)}
        total = counters.get(total_name)
        if per_metric and total is not None and sum(per_metric.values()) != int(total):
            problems.append(
                f"{where}: sum({prefix}*) {sum(per_metric.values())}"
                f" != {total_name} {int(total)}"
            )
    rebuilds = counters.get("cost_model_rebuilds")
    if rebuilds is not None and int(rebuilds) != 0:
        problems.append(f"{where}: cost_model_rebuilds {int(rebuilds)} != 0")
    for name in ROBUSTNESS_COUNTERS + NET_COUNTERS + LANE_COUNTERS:
        v = counters.get(name, 0)
        if int(v) != v or int(v) < 0:
            problems.append(f"{where}: {name} {v!r} is not a non-negative count")
    multi = int(counters.get("kernel_multi_calls", 0))
    filled = int(counters.get("kernel_lanes_filled", 0))
    abandons = int(counters.get("kernel_lane_abandons", 0))
    if filled < 2 * multi:
        problems.append(
            f"{where}: kernel_lanes_filled {filled}"
            f" < 2 * kernel_multi_calls {multi}"
        )
    if abandons > filled:
        problems.append(
            f"{where}: kernel_lane_abandons {abandons}"
            f" > kernel_lanes_filled {filled}"
        )


def audit(doc, label, problems):
    """Check every counters object a document carries."""
    if doc.get("schema") == "repro.metrics.v1":
        check_counters(doc.get("counters", {}), f"{label} snapshot", problems)
        return
    for i, run in enumerate(doc.get("runs", [])):
        counters = run.get("counters")
        if counters:
            check_counters(counters, f"{label} runs[{i}]", problems)
    stats = doc.get("stats")
    if stats:
        if stats.get("schema") != "repro.metrics.v1":
            problems.append(
                f"{label} stats: unsupported schema {stats.get('schema')!r}"
            )
        else:
            check_counters(stats.get("counters", {}), f"{label} stats", problems)


def run_key(run):
    return tuple(sorted((k, v) for k, v in run.items() if k not in MEASUREMENTS))


def print_deltas(base, curr):
    """Timing + dtw_calls deltas for runs present in both documents."""
    base_runs = {run_key(r): r for r in base.get("runs", [])}
    matched = 0
    for run in curr.get("runs", []):
        b = base_runs.get(run_key(run))
        if b is None:
            continue
        matched += 1
        ident = " ".join(
            f"{k}={v}" for k, v in sorted(run.items()) if k not in MEASUREMENTS
        )
        parts = []
        if "ns_per_op" in run and "ns_per_op" in b and b["ns_per_op"]:
            ratio = run["ns_per_op"] / b["ns_per_op"]
            parts.append(f"time x{ratio:.3f}")
        bc, cc = b.get("counters", {}), run.get("counters", {})
        for key in ("dtw_calls", "dtw_abandons", "candidates"):
            if key in bc and key in cc and int(cc[key]) != int(bc[key]):
                parts.append(f"{key} {int(bc[key])} -> {int(cc[key])}")
        # robustness + net + lane counters read absent as 0 on either
        # side, so a new artifact's panics/sheds/conns/lane-packing diff
        # cleanly against an old baseline
        for key in ROBUSTNESS_COUNTERS + NET_COUNTERS + LANE_COUNTERS:
            bv, cv = int(bc.get(key, 0)), int(cc.get(key, 0))
            if bv != cv:
                parts.append(f"{key} {bv} -> {cv}")
        print(f"  {ident}: {', '.join(parts) if parts else 'unchanged'}")
    total = len(curr.get("runs", []))
    print(f"  matched {matched}/{total} runs against the baseline")


def load(path):
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_diff: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def main(argv):
    if len(argv) not in (2, 3):
        print(__doc__.strip(), file=sys.stderr)
        return 2
    docs = [(p, load(p)) for p in argv[1:]]
    problems = []
    for path, doc in docs:
        audit(doc, path, problems)
    if len(docs) == 2:
        print(f"deltas {docs[0][0]} -> {docs[1][0]}:")
        print_deltas(docs[0][1], docs[1][1])
    for p in problems:
        print(f"INVARIANT VIOLATION: {p}", file=sys.stderr)
    if problems:
        return 1
    names = ", ".join(p for p, _ in docs)
    print(f"counter invariants hold: {names}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
