# Layer-2: the batched compute graphs the Rust coordinator executes via PJRT.
#
# Each public function here is a jit-able graph over *fixed* shapes that
# aot.py lowers to HLO text, one artifact per (function, query length). They
# call the Layer-1 Pallas kernels so kernel and graph lower into one module.
#
# All functions return 1-tuples: the AOT bridge lowers with
# ``return_tuple=True`` and the Rust side unwraps with ``to_tuple1()``
# (see /opt/xla-example/load_hlo/).
import jax.numpy as jnp

from .kernels import dtw_batch, lb_keogh_batch, znorm_batch


def batched_znorm(windows):
    """Z-normalise a (batch, n) panel of raw candidate windows."""
    return (znorm_batch(windows),)


def batched_lb_keogh(u, l, z_windows):
    """LB_Keogh of a (batch, n) panel of *z-normalised* windows against the
    query envelopes ``u``/``l`` (n,). Returns (batch,) bounds."""
    return (lb_keogh_batch(u, l, z_windows),)


def prefilter(u, l, raw_windows):
    """The service's batched admission filter: z-normalise raw candidate
    windows, then LB_Keogh them against the query envelopes — fused so the
    normalised panel never leaves VMEM. Returns (batch,) lower bounds; the
    coordinator only sends survivors (lb <= best-so-far) to the scalar
    EAPrunedDTW core."""
    z = znorm_batch(raw_windows)
    return (lb_keogh_batch(u, l, z),)


def batched_dtw(q, w, z_windows):
    """Exact windowed DTW (wavefront, no pruning) of a z-normalised panel
    against query ``q``; ``w`` is a runtime i32 (1,) window. The batch
    verifier for the UcrMonXla suite."""
    return (dtw_batch(q, w, z_windows),)


def prefilter_verify(q, u, l, w, raw_windows):
    """Fused znorm -> LB_Keogh -> wavefront-DTW graph: returns both the
    lower bounds and the exact distances for a raw panel. Used by the
    ablation A3 path where the whole batch is resolved on the XLA side."""
    z = znorm_batch(raw_windows)
    lb = lb_keogh_batch(u, l, z)
    d = dtw_batch(q, w, z)
    return (jnp.stack([lb, d], axis=0),)
