# Layer-1 Pallas kernel: batched z-normalisation.
#
# The UCR suite z-normalises every candidate window before any distance is
# evaluated. On the service's batched path this is the first stage of the
# prefilter pipeline (znorm -> LB_Keogh), fused into a single AOT artifact by
# model.prefilter so XLA keeps the normalised panel in registers/VMEM.
#
# Uses the UCR running-stats identity std = sqrt(E[x^2] - E[x]^2) — the same
# formula the Rust `norm::StreamingStats` implements — so the two paths agree
# bit-for-bit modulo f32 rounding. Near-constant windows (std <= STD_EPS)
# z-normalise to all-zeros, matching the Rust convention.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import STD_EPS

DEFAULT_BLOCK_B = 8


def _znorm_kernel(x_ref, o_ref):
    x = x_ref[...]  # (block_b, n)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    ex2 = jnp.mean(x * x, axis=-1, keepdims=True)
    var = jnp.maximum(ex2 - mean * mean, 0.0)
    std = jnp.sqrt(var)
    safe = std > STD_EPS
    o_ref[...] = jnp.where(safe, (x - mean) / jnp.where(safe, std, 1.0), 0.0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def znorm_batch(x, *, block_b=DEFAULT_BLOCK_B):
    """Z-normalise each row of ``x`` (batch, n) → (batch, n) float32."""
    batch, n = x.shape
    assert batch % block_b == 0, (batch, block_b)
    grid = (batch // block_b,)
    return pl.pallas_call(
        _znorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
