# Layer-1 Pallas kernel: batched LB_Keogh.
#
# TPU mapping of the paper's "prune before you compute" insight (DESIGN.md
# §Hardware-Adaptation): where the CPU algorithm prunes cells *within* one
# DTW matrix, this kernel prunes *across* candidates — a whole batch of
# lower bounds in one VMEM-resident pass, so only survivors reach the scalar
# EAPrunedDTW core in Rust.
#
# Tiling: the grid walks the batch dimension in blocks of ``block_b`` rows;
# each grid step holds a (block_b, n) candidate panel plus one broadcast copy
# of the U/L envelopes in VMEM (block_b=8, n=1024 → 8*1024*4 B = 32 KiB panel
# + 8 KiB envelopes — far under the 16 MiB VMEM budget, leaving room for
# double buffering of the HBM->VMEM stream). The clamp+square is VPU
# elementwise work; the row reduction is a lane reduction inside the tile.
#
# interpret=True always: CPU PJRT cannot run Mosaic custom-calls. Real-TPU
# performance is argued by the VMEM/roofline accounting in DESIGN.md §7.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8


def _lb_keogh_kernel(u_ref, l_ref, c_ref, o_ref):
    c = c_ref[...]  # (block_b, n) candidate panel
    u = u_ref[...]  # (n,) upper envelope (broadcast to the panel)
    l = l_ref[...]  # (n,) lower envelope
    over = jnp.maximum(c - u[None, :], 0.0)
    under = jnp.maximum(l[None, :] - c, 0.0)
    o_ref[...] = jnp.sum(over * over + under * under, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def lb_keogh_batch(u, l, c, *, block_b=DEFAULT_BLOCK_B):
    """LB_Keogh for every row of ``c`` (batch, n) against envelopes ``u``/``l``
    (n,). Returns (batch,) float32. ``batch`` must be a multiple of block_b."""
    batch, n = c.shape
    assert batch % block_b == 0, (batch, block_b)
    grid = (batch // block_b,)
    return pl.pallas_call(
        _lb_keogh_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),          # U: one VMEM copy
            pl.BlockSpec((n,), lambda i: (0,)),          # L: one VMEM copy
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),  # candidate panel
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(u.astype(jnp.float32), l.astype(jnp.float32), c.astype(jnp.float32))
