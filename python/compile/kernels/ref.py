# Pure-python/jnp correctness oracles for the Pallas kernels.
#
# These are the CORE correctness signal for Layer 1: every kernel in this
# package is checked against these references by pytest (+hypothesis sweeps
# over shapes) before anything is AOT-lowered for the Rust runtime.
import numpy as np
import jax.numpy as jnp

__all__ = [
    "znorm_ref",
    "envelopes_ref",
    "lb_keogh_ref",
    "dtw_ref",
    "dtw_batch_ref",
]

# Guard used when a window is (near) constant: the UCR suite convention is to
# treat such a window as flat zeros rather than dividing by ~0.
STD_EPS = 1e-8


def znorm_ref(x):
    """Z-normalise each row of ``x`` (batch, n) using the UCR running-stats
    formula: std = sqrt(E[x^2] - E[x]^2)."""
    x = jnp.asarray(x, jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    ex2 = jnp.mean(x * x, axis=-1, keepdims=True)
    var = jnp.maximum(ex2 - mean * mean, 0.0)
    std = jnp.sqrt(var)
    safe = std > STD_EPS
    return jnp.where(safe, (x - mean) / jnp.where(safe, std, 1.0), 0.0)


def envelopes_ref(q, w):
    """Keogh envelopes of ``q`` (n,) for warping window ``w`` (cells):
    U[i] = max(q[i-w..i+w]), L[i] = min(q[i-w..i+w]). O(n*w) naive oracle
    for the Rust Lemire implementation and for building kernel inputs."""
    q = np.asarray(q, np.float32)
    n = q.shape[0]
    u = np.empty(n, np.float32)
    l = np.empty(n, np.float32)
    for i in range(n):
        lo, hi = max(0, i - w), min(n, i + w + 1)
        u[i] = q[lo:hi].max()
        l[i] = q[lo:hi].min()
    return u, l


def lb_keogh_ref(u, l, c):
    """LB_Keogh of each candidate row ``c`` (batch, n) against the query
    envelopes ``u``/``l`` (n,). Squared-Euclidean cost, as in the UCR suite."""
    u = jnp.asarray(u, jnp.float32)
    l = jnp.asarray(l, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    over = jnp.maximum(c - u[None, :], 0.0)
    under = jnp.maximum(l[None, :] - c, 0.0)
    return jnp.sum(over * over + under * under, axis=-1)


def dtw_ref(q, c, w=None):
    """Windowed DTW (squared Euclidean cost) between 1-D ``q`` and ``c``.

    Full-matrix numpy DP — the slow, obviously-correct oracle (Algorithm 1
    of the paper plus the Sakoe-Chiba band of §2.1). ``w=None`` = no window.
    """
    q = np.asarray(q, np.float64)
    c = np.asarray(c, np.float64)
    n, m = len(q), len(c)
    if w is None:
        w = max(n, m)
    D = np.full((n + 1, m + 1), np.inf)
    D[0, 0] = 0.0
    for i in range(1, n + 1):
        lo = max(1, i - w)
        hi = min(m, i + w)
        for j in range(lo, hi + 1):
            cost = (q[i - 1] - c[j - 1]) ** 2
            D[i, j] = cost + min(D[i - 1, j], D[i, j - 1], D[i - 1, j - 1])
    return D[n, m]


def dtw_batch_ref(q, cands, w):
    """Batched windowed DTW oracle: ``q`` (n,), ``cands`` (batch, n)."""
    return np.array([dtw_ref(q, cands[b], w) for b in range(cands.shape[0])],
                    np.float32)
