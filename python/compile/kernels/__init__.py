# L1: Pallas kernels for the batched prefilter/verify path.
from .lb_keogh import lb_keogh_batch
from .znorm import znorm_batch
from .dtw_wavefront import dtw_batch
from . import ref

__all__ = ["lb_keogh_batch", "znorm_batch", "dtw_batch", "ref"]
