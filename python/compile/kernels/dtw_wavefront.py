# Layer-1 Pallas kernel: batched anti-diagonal (wavefront) windowed DTW.
#
# This is the "vectorised DTW" comparator the paper cites (Xiao et al. [22]
# parallelise DTW on GPU with prefix computations) re-thought for TPU
# (DESIGN.md §Hardware-Adaptation): the DP recurrence has no intra-diagonal
# dependency, so diagonal k is one vector op over the whole batch panel.
# Three diagonals (k, k-1, k-2) of shape (block_b, n+1) stay VMEM-resident;
# the scan over 2n-1 diagonals is a lax.fori_loop *inside* the kernel body,
# i.e. the HBM<->VMEM traffic is one candidate panel in, one distance vector
# out, per grid step.
#
# No pruning happens here — pruning is data-dependent and branchy, which is
# exactly why the paper's EAPrunedDTW lives in the Rust scalar core. This
# kernel is the batch *verifier* used by the UcrMonXla suite and the exact
# DTW used to double-check survivors of the LB prefilter.
#
# The warping window ``w`` is a runtime scalar (i32), so one AOT artifact
# per query length serves every window ratio in the paper's grid.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8
INF = float("inf")  # plain python float: jnp array constants can't be
                    # captured by a pallas kernel body


def _dtw_kernel(q_ref, w_ref, c_ref, o_ref):
    q = q_ref[...]        # (n,) z-normalised query
    c = c_ref[...]        # (block_b, n) candidate panel
    w = w_ref[0]          # scalar warping window, in cells
    bb, n = c.shape
    idx = jnp.arange(n + 1)
    # qp[i] = q[i-1] (1-based DP indexing); cp[:, j] = c[:, j-1].
    qp = jnp.concatenate([jnp.zeros((1,), jnp.float32), q])
    cp = jnp.concatenate([jnp.zeros((bb, 1), jnp.float32), c], axis=1)

    def shift(a):  # a[:, i] -> a[:, i-1], INF border at i=0
        return jnp.concatenate([jnp.full((bb, 1), INF), a[:, :-1]], axis=1)

    # Diagonal k holds cells (i, j=k-i). k=0: only (0,0)=0. k=1: borders.
    dm2 = jnp.broadcast_to(jnp.where(idx == 0, 0.0, INF), (bb, n + 1))
    dm1 = jnp.full((bb, n + 1), INF)

    def body(k, carry):
        dm2, dm1 = carry
        j = k - idx
        valid = (idx >= 1) & (j >= 1) & (j <= n) & (jnp.abs(idx - j) <= w)
        cj = jnp.take(cp, jnp.clip(j, 0, n), axis=1)       # (bb, n+1)
        cost = (qp[None, :] - cj) ** 2
        # D[i-1,j] -> shift(dm1); D[i,j-1] -> dm1; D[i-1,j-1] -> shift(dm2)
        best = jnp.minimum(jnp.minimum(shift(dm1), dm1), shift(dm2))
        d = jnp.where(valid[None, :], cost + best, INF)
        return (dm1, d)

    dm2, dm1 = jax.lax.fori_loop(2, 2 * n + 1, body, (dm2, dm1))
    o_ref[...] = dm1[:, n]  # diagonal k=2n, cell (n, n)


@functools.partial(jax.jit, static_argnames=("block_b",))
def dtw_batch(q, w, c, *, block_b=DEFAULT_BLOCK_B):
    """Windowed DTW between ``q`` (n,) and every row of ``c`` (batch, n).

    ``w`` is an i32 scalar array of shape (1,) — the Sakoe-Chiba band width
    in cells. Returns (batch,) float32 exact distances (no pruning)."""
    batch, n = c.shape
    assert q.shape == (n,), (q.shape, c.shape)
    assert batch % block_b == 0, (batch, block_b)
    grid = (batch // block_b,)
    return pl.pallas_call(
        _dtw_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),            # query
            pl.BlockSpec((1,), lambda i: (0,)),            # window scalar
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),  # candidate panel
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), w.astype(jnp.int32), c.astype(jnp.float32))
