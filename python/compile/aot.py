# AOT bridge: lower the Layer-2 graphs to HLO *text* artifacts for the Rust
# PJRT runtime.
#
# HLO text — NOT ``lowered.compile()`` or proto ``.serialize()`` — is the
# interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
# ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
# INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
# /opt/xla-example/load_hlo/ and its README.
#
# Run as ``python -m compile.aot --out-dir ../artifacts`` (what `make
# artifacts` does). Python runs ONCE here; the Rust binary is self-contained
# afterwards.
import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The paper's experiment grid uses query lengths {128, 256, 512, 1024}
# (prefixes of 1024-point queries). One artifact per (graph, length); the
# warping window is a *runtime* input so all five window ratios share one
# artifact. BATCH is the coordinator's panel size.
QUERY_LENGTHS = (128, 256, 512, 1024)
BATCH = 64

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def graphs_for(n: int, batch: int):
    """(name, fn, example_args) for every artifact at query length ``n``."""
    return [
        (f"znorm_b{batch}_n{n}", model.batched_znorm,
         (_spec((batch, n)),)),
        (f"lb_keogh_b{batch}_n{n}", model.batched_lb_keogh,
         (_spec((n,)), _spec((n,)), _spec((batch, n)))),
        (f"prefilter_b{batch}_n{n}", model.prefilter,
         (_spec((n,)), _spec((n,)), _spec((batch, n)))),
        (f"dtw_b{batch}_n{n}", model.batched_dtw,
         (_spec((n,)), _spec((1,), I32), _spec((batch, n)))),
        (f"prefilter_verify_b{batch}_n{n}", model.prefilter_verify,
         (_spec((n,)), _spec((n,)), _spec((n,)), _spec((1,), I32),
          _spec((batch, n)))),
    ]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name, fn, args, out_dir):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args
        ],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--lengths", type=int, nargs="*", default=QUERY_LENGTHS)
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"batch": args.batch, "lengths": list(args.lengths),
                "artifacts": []}
    for n in args.lengths:
        for name, fn, specs in graphs_for(n, args.batch):
            entry = lower_one(name, fn, specs, args.out_dir)
            manifest["artifacts"].append(entry)
            print(f"  wrote {entry['file']} ({entry['bytes']} bytes)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> "
          f"{args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
