"""Optional-hypothesis shim.

The property sweeps use hypothesis when it is installed; in environments
without it (the build image pins a minimal package set) the sweep tests
skip cleanly instead of breaking collection for the whole suite.

Usage in test modules:

    from hypothesis_compat import given, settings, st
"""

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - depends on the image
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            @pytest.mark.skip(
                reason="hypothesis not installed; property sweep skipped"
            )
            def _skipped():
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _StrategyStub:
        """st.integers(...), st.floats(...), ... — inert placeholders."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()
