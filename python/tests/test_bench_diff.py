# Tooling: tools/bench_diff.py audits BENCH_*.json / metrics-snapshot
# documents against the counter-conservation identities (the same ones
# rust/tests/prop_invariants.rs property-tests in-process) and diffs two
# artifacts. Stdlib-only — no jax needed.
import copy
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
TOOL = REPO / "tools" / "bench_diff.py"
BASELINE = REPO / "tools" / "baseline" / "BENCH_strip_throughput.json"


def run_tool(*paths):
    return subprocess.run(
        [sys.executable, str(TOOL)] + [str(p) for p in paths],
        capture_output=True,
        text=True,
    )


def test_baseline_fixture_passes_the_audit():
    res = run_tool(BASELINE)
    assert res.returncode == 0, res.stderr
    assert "invariants hold" in res.stdout


def test_two_file_mode_prints_deltas_and_passes():
    res = run_tool(BASELINE, BASELINE)
    assert res.returncode == 0, res.stderr
    assert "deltas" in res.stdout
    assert "matched 2/2 runs" in res.stdout


def _corrupt(doc, tweak):
    bad = copy.deepcopy(doc)
    tweak(bad)
    return bad


def test_violations_fail_with_exit_1(tmp_path):
    doc = json.loads(BASELINE.read_text())

    def broken_conservation(d):
        d["runs"][0]["counters"]["dtw_calls"] += 1

    def broken_outcomes(d):
        d["stats"]["counters"]["dtw_abandons"] += 7

    def broken_metric_sums(d):
        d["stats"]["counters"]["metric_calls_msm"] = 5

    def rebuilds_nonzero(d):
        d["runs"][1]["counters"]["cost_model_rebuilds"] = 2

    def improved_breaks_conservation(d):
        # lb_improved_prunes is part of the stage-prune sum: inflating it
        # alone must break candidates == prunes + dtw_calls
        d["runs"][0]["counters"]["lb_improved_prunes"] = (
            d["runs"][0]["counters"].get("lb_improved_prunes", 0) + 5
        )

    for name, tweak in [
        ("conservation", broken_conservation),
        ("outcomes", broken_outcomes),
        ("metric_sums", broken_metric_sums),
        ("rebuilds", rebuilds_nonzero),
        ("improved_conservation", improved_breaks_conservation),
    ]:
        p = tmp_path / f"{name}.json"
        p.write_text(json.dumps(_corrupt(doc, tweak)))
        res = run_tool(p)
        assert res.returncode == 1, f"{name}: {res.stdout}{res.stderr}"
        assert "INVARIANT VIOLATION" in res.stderr, name


def test_bare_snapshot_documents_are_audited(tmp_path):
    doc = json.loads(BASELINE.read_text())
    snap = doc["stats"]
    good = tmp_path / "snap.json"
    good.write_text(json.dumps(snap))
    assert run_tool(good).returncode == 0

    bad_doc = copy.deepcopy(snap)
    bad_doc["counters"]["candidates"] += 3
    bad = tmp_path / "snap_bad.json"
    bad.write_text(json.dumps(bad_doc))
    res = run_tool(bad)
    assert res.returncode == 1
    assert "candidates" in res.stderr


def test_missing_counters_are_skipped_not_failed(tmp_path):
    # a pre-observability artifact lacks dtw_completions / xla_prunes:
    # the identities that need them are skipped, nothing fails
    legacy = {
        "bench": "old",
        "runs": [
            {
                "qlen": 128,
                "counters": {"candidates": 10, "dtw_calls": 4, "dtw_abandons": 3},
            }
        ],
    }
    p = tmp_path / "legacy.json"
    p.write_text(json.dumps(legacy))
    res = run_tool(p)
    assert res.returncode == 0, res.stderr


def test_absent_improved_counter_reads_as_zero(tmp_path):
    # an artifact from before the LB_Improved stage has the original four
    # stage counters but no lb_improved_prunes: the conservation identity
    # still runs, with the missing stage read as 0
    doc = {
        "bench": "pre_improved",
        "runs": [
            {
                "qlen": 64,
                "counters": {
                    "candidates": 10,
                    "lb_kim_prunes": 3,
                    "lb_keogh_eq_prunes": 2,
                    "lb_keogh_ec_prunes": 1,
                    "xla_prunes": 0,
                    "dtw_calls": 4,
                },
            }
        ],
    }
    p = tmp_path / "pre_improved.json"
    p.write_text(json.dumps(doc))
    assert run_tool(p).returncode == 0

    # ...and a violation hidden behind the default is still caught
    doc["runs"][0]["counters"]["dtw_calls"] = 3
    p.write_text(json.dumps(doc))
    res = run_tool(p)
    assert res.returncode == 1
    assert "INVARIANT VIOLATION" in res.stderr


def test_absent_robustness_counters_read_as_zero(tmp_path):
    # a pre-robustness snapshot carries none of worker_panics /
    # worker_respawns / shed_queries / deadline_timeouts: the audit
    # passes (absent reads as 0, not unknown)
    doc = json.loads(BASELINE.read_text())
    snap = copy.deepcopy(doc["stats"])
    for name in (
        "worker_panics",
        "worker_respawns",
        "shed_queries",
        "deadline_timeouts",
    ):
        snap["counters"].pop(name, None)
    p = tmp_path / "pre_robustness.json"
    p.write_text(json.dumps(snap))
    res = run_tool(p)
    assert res.returncode == 0, res.stderr


def test_present_robustness_counters_are_validated_and_diffed(tmp_path):
    doc = json.loads(BASELINE.read_text())
    snap = copy.deepcopy(doc["stats"])
    snap["counters"]["shed_queries"] = 3
    snap["counters"]["deadline_timeouts"] = 1
    curr = tmp_path / "faulty_run.json"
    curr.write_text(json.dumps(snap))
    # well-formed counts pass the audit
    assert run_tool(curr).returncode == 0

    # a negative count is a hard failure
    bad = copy.deepcopy(snap)
    bad["counters"]["worker_panics"] = -2
    badp = tmp_path / "negative.json"
    badp.write_text(json.dumps(bad))
    res = run_tool(badp)
    assert res.returncode == 1
    assert "worker_panics" in res.stderr


def test_robustness_deltas_print_against_a_counterless_baseline(tmp_path):
    # baseline runs predate the robustness counters entirely; the current
    # artifact sheds twice — the delta reads the absent side as 0
    doc = json.loads(BASELINE.read_text())
    curr_doc = copy.deepcopy(doc)
    for run in curr_doc["runs"]:
        run["counters"]["shed_queries"] = 2
    base = tmp_path / "base.json"
    curr = tmp_path / "curr.json"
    base.write_text(json.dumps(doc))
    curr.write_text(json.dumps(curr_doc))
    res = run_tool(base, curr)
    assert res.returncode == 0, res.stderr
    assert "shed_queries 0 -> 2" in res.stdout


def test_absent_net_counters_read_as_zero(tmp_path):
    # a pre-net snapshot carries none of conns_accepted / conns_rejected /
    # conn_read_timeouts / quota_shed_queries: the audit passes (absent
    # reads as 0, not unknown — no TCP front-end existed)
    doc = json.loads(BASELINE.read_text())
    snap = copy.deepcopy(doc["stats"])
    for name in (
        "conns_accepted",
        "conns_rejected",
        "conn_read_timeouts",
        "quota_shed_queries",
    ):
        snap["counters"].pop(name, None)
    p = tmp_path / "pre_net.json"
    p.write_text(json.dumps(snap))
    res = run_tool(p)
    assert res.returncode == 0, res.stderr


def test_present_net_counters_are_validated_and_diffed(tmp_path):
    doc = json.loads(BASELINE.read_text())
    snap = copy.deepcopy(doc["stats"])
    snap["counters"]["conns_accepted"] = 5
    snap["counters"]["conns_rejected"] = 1
    snap["counters"]["quota_shed_queries"] = 2
    curr = tmp_path / "net_run.json"
    curr.write_text(json.dumps(snap))
    # well-formed counts pass the audit
    assert run_tool(curr).returncode == 0

    # a non-integer count is a hard failure
    bad = copy.deepcopy(snap)
    bad["counters"]["conn_read_timeouts"] = 1.5
    badp = tmp_path / "fractional.json"
    badp.write_text(json.dumps(bad))
    res = run_tool(badp)
    assert res.returncode == 1
    assert "conn_read_timeouts" in res.stderr

    # ...and so is a negative one
    bad2 = copy.deepcopy(snap)
    bad2["counters"]["conns_rejected"] = -1
    bad2p = tmp_path / "negative_net.json"
    bad2p.write_text(json.dumps(bad2))
    res = run_tool(bad2p)
    assert res.returncode == 1
    assert "conns_rejected" in res.stderr


def test_net_deltas_print_against_a_counterless_baseline(tmp_path):
    # baseline runs predate the net counters entirely; the current
    # artifact saw two quota sheds — the delta reads the absent side as 0
    doc = json.loads(BASELINE.read_text())
    curr_doc = copy.deepcopy(doc)
    for run in curr_doc["runs"]:
        run["counters"]["quota_shed_queries"] = 2
    base = tmp_path / "base.json"
    curr = tmp_path / "curr.json"
    base.write_text(json.dumps(doc))
    curr.write_text(json.dumps(curr_doc))
    res = run_tool(base, curr)
    assert res.returncode == 0, res.stderr
    assert "quota_shed_queries 0 -> 2" in res.stdout


def test_absent_lane_counters_read_as_zero(tmp_path):
    # a pre-wavefront snapshot carries none of kernel_multi_calls /
    # kernel_lanes_filled / kernel_lane_abandons: the audit passes
    # (absent reads as 0, not unknown — no lane packing existed)
    doc = json.loads(BASELINE.read_text())
    snap = copy.deepcopy(doc["stats"])
    for name in (
        "kernel_multi_calls",
        "kernel_lanes_filled",
        "kernel_lane_abandons",
    ):
        snap["counters"].pop(name, None)
    p = tmp_path / "pre_lanes.json"
    p.write_text(json.dumps(snap))
    res = run_tool(p)
    assert res.returncode == 0, res.stderr


def test_present_lane_counters_are_validated_and_diffed(tmp_path):
    doc = json.loads(BASELINE.read_text())
    snap = copy.deepcopy(doc["stats"])
    snap["counters"]["kernel_multi_calls"] = 3
    snap["counters"]["kernel_lanes_filled"] = 10
    snap["counters"]["kernel_lane_abandons"] = 4
    curr = tmp_path / "lanes_run.json"
    curr.write_text(json.dumps(snap))
    # well-formed counts pass the audit
    assert run_tool(curr).returncode == 0

    # a fractional count is a hard failure
    bad = copy.deepcopy(snap)
    bad["counters"]["kernel_lanes_filled"] = 2.5
    badp = tmp_path / "fractional_lanes.json"
    badp.write_text(json.dumps(bad))
    res = run_tool(badp)
    assert res.returncode == 1
    assert "kernel_lanes_filled" in res.stderr

    # ...and so is a negative one
    bad2 = copy.deepcopy(snap)
    bad2["counters"]["kernel_lane_abandons"] = -1
    bad2p = tmp_path / "negative_lanes.json"
    bad2p.write_text(json.dumps(bad2))
    res = run_tool(bad2p)
    assert res.returncode == 1
    assert "kernel_lane_abandons" in res.stderr


def test_lane_occupancy_invariants_are_enforced(tmp_path):
    doc = json.loads(BASELINE.read_text())

    # a multi-lane call carries >= 2 lanes by definition: 3 calls cannot
    # have filled only 4 lanes
    under = copy.deepcopy(doc["stats"])
    under["counters"]["kernel_multi_calls"] = 3
    under["counters"]["kernel_lanes_filled"] = 4
    p = tmp_path / "under_occupied.json"
    p.write_text(json.dumps(under))
    res = run_tool(p)
    assert res.returncode == 1
    assert "kernel_lanes_filled" in res.stderr

    # lane abandons are a subset of lanes filled
    over = copy.deepcopy(doc["stats"])
    over["counters"]["kernel_multi_calls"] = 2
    over["counters"]["kernel_lanes_filled"] = 5
    over["counters"]["kernel_lane_abandons"] = 6
    p2 = tmp_path / "over_abandoned.json"
    p2.write_text(json.dumps(over))
    res = run_tool(p2)
    assert res.returncode == 1
    assert "kernel_lane_abandons" in res.stderr


def test_lane_deltas_print_against_a_counterless_baseline(tmp_path):
    # baseline runs predate the lane counters entirely; the current
    # artifact packed lanes — the delta reads the absent side as 0
    doc = json.loads(BASELINE.read_text())
    curr_doc = copy.deepcopy(doc)
    for run in curr_doc["runs"]:
        run["counters"]["kernel_multi_calls"] = 2
        run["counters"]["kernel_lanes_filled"] = 7
    base = tmp_path / "base.json"
    curr = tmp_path / "curr.json"
    base.write_text(json.dumps(doc))
    curr.write_text(json.dumps(curr_doc))
    res = run_tool(base, curr)
    assert res.returncode == 0, res.stderr
    assert "kernel_lanes_filled 0 -> 7" in res.stdout


def test_unreadable_file_is_a_usage_error(tmp_path):
    res = run_tool(tmp_path / "nope.json")
    assert res.returncode == 2
