# Layer-2: the composed graphs (prefilter, prefilter_verify) agree with the
# composition of their parts and with the oracles.
import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import (
    znorm_ref, lb_keogh_ref, envelopes_ref, dtw_batch_ref)


def _mk(rng, b=8, n=32, w=4):
    q = znorm_ref(rng.normal(size=(1, n)).astype(np.float32))[0]
    u, l = envelopes_ref(np.array(q), w)
    raw = rng.normal(3.0, 2.0, size=(b, n)).astype(np.float32)
    return np.array(q), u, l, raw, w


def test_prefilter_equals_znorm_then_lb(rng):
    q, u, l, raw, w = _mk(rng)
    (got,) = model.prefilter(jnp.array(u), jnp.array(l), jnp.array(raw))
    z = znorm_ref(raw)
    want = lb_keogh_ref(u, l, z)
    np.testing.assert_allclose(np.array(got), np.array(want),
                               rtol=1e-4, atol=1e-5)


def test_prefilter_is_lower_bound_on_dtw_of_znormed(rng):
    q, u, l, raw, w = _mk(rng)
    (lb,) = model.prefilter(jnp.array(u), jnp.array(l), jnp.array(raw))
    z = np.array(znorm_ref(raw))
    d = dtw_batch_ref(q, z, w)
    assert np.all(np.array(lb) <= d + 1e-3)


def test_prefilter_verify_stacks_lb_and_dtw(rng):
    q, u, l, raw, w = _mk(rng)
    (both,) = model.prefilter_verify(
        jnp.array(q), jnp.array(u), jnp.array(l),
        jnp.array([w], dtype=jnp.int32), jnp.array(raw))
    both = np.array(both)
    assert both.shape == (2, raw.shape[0])
    lb, d = both[0], both[1]
    z = np.array(znorm_ref(raw))
    np.testing.assert_allclose(lb, np.array(lb_keogh_ref(u, l, z)),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(d, dtw_batch_ref(q, z, w), rtol=1e-3,
                               atol=1e-4)
    assert np.all(lb <= d + 1e-3)


def test_batched_znorm_tuple_contract(rng):
    raw = rng.normal(size=(8, 16)).astype(np.float32)
    out = model.batched_znorm(jnp.array(raw))
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(np.array(out[0]), np.array(znorm_ref(raw)),
                               rtol=1e-4, atol=1e-5)


def test_batched_dtw_tuple_contract(rng):
    q, u, l, raw, w = _mk(rng)
    z = np.array(znorm_ref(raw))
    out = model.batched_dtw(jnp.array(q), jnp.array([w], dtype=jnp.int32),
                            jnp.array(z))
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_allclose(np.array(out[0]), dtw_batch_ref(q, z, w),
                               rtol=1e-3, atol=1e-4)
