# AOT bridge: every graph lowers to parseable HLO text with the expected
# entry signature, and the manifest indexes it correctly. Uses a small
# length so the test is fast; `make artifacts` runs the full grid.
import json
import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(d),
         "--lengths", "16", "--batch", "8"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    return d


def test_all_artifacts_written(art_dir):
    names = {e["name"] for e in
             json.load(open(art_dir / "manifest.json"))["artifacts"]}
    assert names == {
        "znorm_b8_n16", "lb_keogh_b8_n16", "prefilter_b8_n16",
        "dtw_b8_n16", "prefilter_verify_b8_n16"}
    for n in names:
        assert (art_dir / f"{n}.hlo.txt").exists()


def test_hlo_text_looks_like_hlo(art_dir):
    text = (art_dir / "dtw_b8_n16.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_shapes(art_dir):
    man = json.load(open(art_dir / "manifest.json"))
    by_name = {e["name"]: e for e in man["artifacts"]}
    pf = by_name["prefilter_b8_n16"]
    assert [i["shape"] for i in pf["inputs"]] == [[16], [16], [8, 16]]
    dt = by_name["dtw_b8_n16"]
    assert [i["shape"] for i in dt["inputs"]] == [[16], [1], [8, 16]]
    assert dt["inputs"][1]["dtype"] == "int32"


def test_manifest_hashes_match_files(art_dir):
    import hashlib
    man = json.load(open(art_dir / "manifest.json"))
    for e in man["artifacts"]:
        text = (art_dir / e["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]
        assert len(text) == e["bytes"]


def test_graphs_for_covers_every_model_fn():
    names = [n for (n, _, _) in aot.graphs_for(16, 8)]
    assert len(names) == len(set(names)) == 5
