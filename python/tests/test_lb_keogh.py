# Layer-1: LB_Keogh Pallas kernel vs pure-jnp oracle, plus the lower-bound
# property LB_Keogh <= DTW that the whole cascade relies on.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from compile.kernels import lb_keogh_batch
from compile.kernels.ref import lb_keogh_ref, envelopes_ref, dtw_ref


def _check(u, l, c, block_b=8):
    got = np.array(lb_keogh_batch(jnp.array(u), jnp.array(l), jnp.array(c),
                                  block_b=block_b))
    want = np.array(lb_keogh_ref(u, l, c))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    return got


def test_basic(rng):
    n = 64
    q = rng.normal(size=n).astype(np.float32)
    u, l = envelopes_ref(q, 5)
    c = rng.normal(size=(16, n)).astype(np.float32)
    _check(u, l, c)


def test_candidate_inside_envelope_is_zero(rng):
    n = 32
    q = rng.normal(size=n).astype(np.float32)
    u, l = envelopes_ref(q, 4)
    # the query itself lies within its own envelope
    c = np.broadcast_to(q, (8, n)).copy()
    got = _check(u, l, c)
    assert np.all(got == 0.0)


def test_far_candidate_positive(rng):
    n = 32
    q = rng.normal(size=n).astype(np.float32)
    u, l = envelopes_ref(q, 4)
    c = (q + 100.0).reshape(1, n).repeat(8, axis=0)
    got = _check(u, l, c)
    assert np.all(got > 0.0)


def test_lb_is_lower_bound_on_dtw(rng):
    """LB_Keogh(q, c) <= DTW_w(q, c) — the invariant the UCR cascade needs."""
    n, w = 24, 3
    for _ in range(10):
        q = rng.normal(size=n).astype(np.float32)
        c = rng.normal(size=(8, n)).astype(np.float32)
        u, l = envelopes_ref(q, w)
        lb = _check(u, l, c)
        for b in range(8):
            d = dtw_ref(q, c[b], w)
            assert lb[b] <= d + 1e-4, (lb[b], d)


def test_wider_window_gives_looser_bound(rng):
    n = 40
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(8, n)).astype(np.float32)
    prev = np.full(8, np.inf)
    for w in (1, 3, 8, 20, n):
        u, l = envelopes_ref(q, w)
        lb = _check(u, l, c)
        assert np.all(lb <= prev + 1e-5)
        prev = lb


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 80),
    w=st.integers(0, 20),
    b_blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(n, w, b_blocks, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=n).astype(np.float32)
    u, l = envelopes_ref(q, min(w, n))
    c = rng.normal(size=(4 * b_blocks, n)).astype(np.float32)
    _check(u, l, c, block_b=4)


def test_rejects_unaligned_batch(rng):
    q = rng.normal(size=16).astype(np.float32)
    u, l = envelopes_ref(q, 2)
    c = rng.normal(size=(5, 16)).astype(np.float32)
    with pytest.raises(AssertionError):
        lb_keogh_batch(jnp.array(u), jnp.array(l), jnp.array(c), block_b=8)
