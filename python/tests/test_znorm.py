# Layer-1: znorm Pallas kernel vs pure-jnp oracle.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from compile.kernels import znorm_batch
from compile.kernels.ref import znorm_ref


def _check(x, block_b=8, rtol=1e-4, atol=1e-5):
    got = np.array(znorm_batch(jnp.array(x), block_b=block_b))
    want = np.array(znorm_ref(x))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)
    return got


def test_basic(rng):
    x = rng.normal(2.0, 5.0, size=(16, 64)).astype(np.float32)
    z = _check(x)
    # each row ends up ~N(0,1)
    np.testing.assert_allclose(z.mean(axis=1), 0.0, atol=1e-4)
    np.testing.assert_allclose((z * z).mean(axis=1), 1.0, rtol=1e-3)


def test_constant_rows_become_zero(rng):
    x = np.full((8, 32), 3.25, np.float32)
    z = _check(x)
    assert np.all(z == 0.0)


def test_mixed_constant_and_normal_rows(rng):
    x = rng.normal(size=(8, 32)).astype(np.float32)
    x[3] = -1.5
    z = _check(x)
    assert np.all(z[3] == 0.0)
    assert np.any(z[2] != 0.0)


def test_scale_shift_invariance(rng):
    x = rng.normal(size=(8, 48)).astype(np.float32)
    z1 = _check(x)
    z2 = _check((x * 7.5 + 100.0).astype(np.float32))
    np.testing.assert_allclose(z1, z2, rtol=1e-2, atol=1e-3)


def test_single_block(rng):
    x = rng.normal(size=(8, 16)).astype(np.float32)
    got8 = np.array(znorm_batch(jnp.array(x), block_b=8))
    got4 = np.array(znorm_batch(jnp.array(x), block_b=4))
    np.testing.assert_allclose(got8, got4, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    n=st.integers(2, 96),
    loc=st.floats(-50, 50),
    scale=st.floats(0.01, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(b_blocks, n, loc, scale, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(loc, scale, size=(4 * b_blocks, n)).astype(np.float32)
    # E[x^2]-E[x]^2 cancels catastrophically in f32 when |loc| >> scale:
    # both kernel and oracle lose the same leading digits but not
    # bit-identically, so the sweep tolerance scales with the conditioning.
    cond = 1.0 + (abs(loc) / max(scale, 1e-3)) ** 2
    tol = min(1e-4 * cond, 0.2)
    _check(x, block_b=4, rtol=max(1e-4, tol), atol=max(1e-5, tol))


def test_rejects_unaligned_batch(rng):
    x = rng.normal(size=(7, 16)).astype(np.float32)
    with pytest.raises(AssertionError):
        znorm_batch(jnp.array(x), block_b=8)
