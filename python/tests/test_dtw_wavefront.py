# Layer-1: wavefront DTW Pallas kernel vs full-matrix DP oracle.
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st

from compile.kernels import dtw_batch
from compile.kernels.ref import dtw_ref, dtw_batch_ref


def _run(q, c, w, block_b=4):
    return np.array(
        dtw_batch(jnp.array(q), jnp.array([w], dtype=jnp.int32),
                  jnp.array(c), block_b=block_b))


def test_paper_worked_example():
    """S=(3,1,4,4,1,1), T=(1,3,2,1,2,2) -> DTW = 9 (paper Fig. 2)."""
    s = np.array([3, 1, 4, 4, 1, 1], np.float32)
    t = np.array([1, 3, 2, 1, 2, 2], np.float32)
    got = _run(s, np.stack([t] * 4), w=6)
    np.testing.assert_allclose(got, 9.0)


def test_identity_is_zero(rng):
    q = rng.normal(size=32).astype(np.float32)
    got = _run(q, np.stack([q] * 4), w=5)
    np.testing.assert_allclose(got, 0.0, atol=1e-5)


def test_window_zero_is_squared_euclidean(rng):
    """w=0 degenerates to the squared Euclidean distance (paper §2.1)."""
    n = 24
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(4, n)).astype(np.float32)
    got = _run(q, c, w=0)
    want = ((c - q[None, :]) ** 2).sum(axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_full_window_is_dtw(rng):
    n = 20
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(4, n)).astype(np.float32)
    got = _run(q, c, w=n)
    want = dtw_batch_ref(q, c, n)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_monotone_in_window(rng):
    """DTW_w is non-increasing in w."""
    n = 16
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(4, n)).astype(np.float32)
    prev = np.full(4, np.inf)
    for w in (0, 1, 2, 4, 8, n):
        got = _run(q, c, w)
        assert np.all(got <= prev + 1e-4)
        prev = got


def test_batch_rows_independent(rng):
    n = 16
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(8, n)).astype(np.float32)
    full = _run(q, c, w=3, block_b=4)
    for b in range(8):
        solo = _run(q, np.stack([c[b]] * 4), w=3)
        np.testing.assert_allclose(full[b], solo[0], rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(2, 40),
    wfrac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(n, wfrac, seed):
    rng = np.random.default_rng(seed)
    w = int(round(wfrac * n))
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(4, n)).astype(np.float32)
    got = _run(q, c, w)
    want = dtw_batch_ref(q, c, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_runtime_window_matches_static_oracle(rng):
    """One artifact serves all window ratios: sweep w at runtime."""
    n = 24
    q = rng.normal(size=n).astype(np.float32)
    c = rng.normal(size=(4, n)).astype(np.float32)
    for ratio in (0.1, 0.2, 0.3, 0.4, 0.5):
        w = max(1, int(round(ratio * n)))
        np.testing.assert_allclose(
            _run(q, c, w), dtw_batch_ref(q, c, w), rtol=1e-4)
